//! Negotiable wire codecs for the model data plane.
//!
//! The data plane moves tensor payloads as raw byte chunks; a *codec*
//! decides what those bytes are. Three codecs are spoken today, offered
//! and accepted in the `Hello`/`HelloAck` handshake and carried per
//! stream by `ModelStreamBegin`:
//!
//! * [`CodecId::F32`] — today's tensor-as-bytes path: 4 bytes/element,
//!   little-endian, bitwise lossless (the §3 baseline).
//! * [`CodecId::Bf16`] — half-precision truncation (round-to-nearest-even
//!   bf16), 2 bytes/element. Lossy: the receiver widens back to f32 on
//!   decode and every downstream accumulation stays f32/f64, so only the
//!   wire pays the precision cut. Error is bounded by bf16's 8 mantissa
//!   bits (≤ 2⁻⁸ relative per element, property-tested).
//! * [`CodecId::Delta`] — XOR of the current f32 bit pattern against a
//!   **base model both peers hold** (the last community model the peer
//!   acknowledged). 4 bytes/element, bitwise lossless, and the bytes are
//!   overwhelmingly zero when the model moved little — the stream is
//!   cheap to squeeze with any byte-level compressor and cheap to
//!   checksum. Requires a shared base; senders fall back to full `F32`
//!   when no base is shared (new learner, stale round, async staleness).
//! * [`CodecId::DeltaRle`] — the entropy-coded delta wire: the XOR
//!   residual's four byte planes are transposed (byte-shuffle: all sign/
//!   exponent bytes run together, where small updates leave long zero
//!   runs) and zero-run-length encoded, with a per-frame escape to raw
//!   residual bytes when compression would expand. Bitwise lossless;
//!   adversarial payloads stay ≤ f32 size + a small frame header.
//!
//! `F32`/`Bf16`/`Delta` are *element-size-stable*: encoded length is
//! `elems × wire_dtype().size_bytes()`, which is what lets the chunked
//! stream receiver pre-size its decode buffers from the announced layout
//! before any payload byte arrives. `DeltaRle` is **framed**
//! ([`WireCodec::is_framed`]): each `ModelChunk` carries exactly one
//! self-delimiting variable-length frame covering a whole element block,
//! so the receiver decompresses chunk N while chunk N+1 is on the wire.
//! The announced layout still uses the f32 wire dtype — its byte size is
//! the frame stream's upper bound and the decode buffers' true size.

use super::{bf16_bits_to_f32, f32_to_bf16_bits, DType};
use anyhow::{bail, Result};

/// Identity of a wire codec (negotiated in `Hello`, carried per stream).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CodecId {
    /// f32 little-endian tensor-as-bytes (lossless, no base).
    F32,
    /// bf16 truncation, f32 widen on decode (lossy, no base).
    Bf16,
    /// f32 bit-XOR against a shared base model (lossless, needs base).
    Delta,
    /// Byte-shuffled, zero-run-length-coded XOR residual frames
    /// (lossless, needs base, variable-length — see [`DeltaRleCodec`]).
    DeltaRle,
}

impl CodecId {
    /// Every codec this build speaks, in preference order for `auto`
    /// resolution (lossless-and-small first).
    pub const ALL: [CodecId; 4] =
        [CodecId::F32, CodecId::Bf16, CodecId::Delta, CodecId::DeltaRle];

    pub fn code(self) -> u8 {
        match self {
            CodecId::F32 => 0,
            CodecId::Bf16 => 1,
            CodecId::Delta => 2,
            CodecId::DeltaRle => 3,
        }
    }

    pub fn from_code(c: u8) -> Result<CodecId> {
        Ok(match c {
            0 => CodecId::F32,
            1 => CodecId::Bf16,
            2 => CodecId::Delta,
            3 => CodecId::DeltaRle,
            _ => bail!("unknown wire codec code {c}"),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            CodecId::F32 => "f32",
            CodecId::Bf16 => "bf16",
            CodecId::Delta => "delta",
            CodecId::DeltaRle => "delta-rle",
        }
    }

    /// Does a decode round-trip reproduce the input bit for bit?
    pub fn is_lossless(self) -> bool {
        !matches!(self, CodecId::Bf16)
    }

    /// Does this codec need a shared base model on both ends?
    pub fn needs_base(self) -> bool {
        matches!(self, CodecId::Delta | CodecId::DeltaRle)
    }

    /// Does this codec emit self-delimiting variable-length frames
    /// (one per `ModelChunk`) instead of element-size-stable bytes?
    pub fn is_framed(self) -> bool {
        matches!(self, CodecId::DeltaRle)
    }

    /// Element type the encoded bytes are sized as on the wire (the
    /// dtype a stream's `TensorLayoutProto` announces for this codec).
    /// For framed codecs this sizes the *decode buffers* and bounds the
    /// wire stream; actual frame bytes are usually smaller.
    pub fn wire_dtype(self) -> DType {
        match self {
            CodecId::Bf16 => DType::Bf16,
            CodecId::F32 | CodecId::Delta | CodecId::DeltaRle => DType::F32,
        }
    }

    /// Degrade this codec along the lossless chain until the peer's
    /// accepted set contains it: delta-rle falls back to delta, and
    /// anything not accepted falls back to the universal f32 floor.
    /// The single source of truth for learner uploads, the controller
    /// fan-out, and single-target dispatch.
    pub fn degrade_to(self, accepted: &[CodecId]) -> CodecId {
        if accepted.contains(&self) {
            self
        } else if self == CodecId::DeltaRle && accepted.contains(&CodecId::Delta) {
            CodecId::Delta
        } else {
            CodecId::F32
        }
    }

    /// Static codec implementation for this id.
    pub fn codec(self) -> &'static dyn WireCodec {
        match self {
            CodecId::F32 => &F32Codec,
            CodecId::Bf16 => &Bf16Codec,
            CodecId::Delta => &DeltaCodec,
            CodecId::DeltaRle => &DeltaRleCodec,
        }
    }
}

impl std::fmt::Display for CodecId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Intersection of an offered codec set with ours, preserving `ours`'s
/// order — the accept set a `HelloAck` carries.
pub fn negotiate(offered: &[CodecId], ours: &[CodecId]) -> Vec<CodecId> {
    ours.iter().copied().filter(|c| offered.contains(c)).collect()
}

/// One wire codec: element bytes in, element bytes out.
///
/// `base` is the shared base model's elements aligned with `cur`/`dst`
/// (same tensor, same local element range); it MUST be `Some` with a
/// matching length for [`CodecId::Delta`] and is ignored otherwise.
/// Encoded bytes are little-endian regardless of host order.
pub trait WireCodec: Send + Sync {
    fn id(&self) -> CodecId;

    /// Encode `cur` into wire bytes. Element-size-stable codecs produce
    /// exactly `cur.len() × wire_dtype` bytes; framed codecs produce one
    /// self-delimiting frame covering all of `cur`.
    fn encode(&self, cur: &[f32], base: Option<&[f32]>) -> Vec<u8>;

    /// Decode a whole-element span of wire bytes into `dst`. For
    /// element-size-stable codecs `bytes.len()` must equal
    /// `dst.len() × wire_dtype` bytes; for framed codecs `bytes` must be
    /// exactly one frame covering `dst.len()` elements. Panics on
    /// malformed input — trusted-input path (tests, benches); the stream
    /// ingest uses the fallible [`WireCodec::decode_frame`].
    fn decode_into(&self, bytes: &[u8], base: Option<&[f32]>, dst: &mut [f32]);

    /// Does this codec emit self-delimiting variable-length frames?
    /// Mirrors [`CodecId::is_framed`].
    fn is_framed(&self) -> bool {
        false
    }

    /// Append one self-contained frame covering exactly `cur` to `out`.
    /// Element-size-stable codecs append their plain encoding (their
    /// "frame" is the bytes themselves); framed codecs append a header +
    /// compressed payload. `out` need not be empty — callers that ever
    /// want to batch frames into one buffer can; today's senders hand
    /// each frame's buffer to the wire message, so they pass a fresh
    /// `Vec` per frame.
    fn encode_frame_into(&self, cur: &[f32], base: Option<&[f32]>, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.encode(cur, base));
    }

    /// Element count covered by the frame starting at `bytes` (framed
    /// codecs only) — what lets the receiver locate the destination and
    /// base spans before decoding.
    fn frame_elems(&self, _bytes: &[u8]) -> Result<usize> {
        bail!("{} is not a framed codec", self.id().name())
    }

    /// Fallible whole-frame decode — the hostile-input path the stream
    /// ingest uses. Element-size-stable codecs validate the span length
    /// and delegate to [`WireCodec::decode_into`].
    fn decode_frame(&self, bytes: &[u8], base: Option<&[f32]>, dst: &mut [f32]) -> Result<()> {
        let expected = dst.len() * self.id().wire_dtype().size_bytes();
        if bytes.len() != expected {
            bail!("{} span is {} bytes, expected {expected}", self.id().name(), bytes.len());
        }
        self.decode_into(bytes, base, dst);
        Ok(())
    }
}

/// Encode an f32 slice as little-endian bytes — the §3 flatten-and-dump
/// hot path (one memcpy on little-endian hosts), shared by
/// `Tensor::encode_data` and the wire codecs.
pub fn encode_f32_slice_le(data: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(data.len() * 4);
    #[cfg(target_endian = "little")]
    {
        // SAFETY: f32 has no invalid bit patterns; the slice covers
        // exactly the initialized storage.
        let bytes =
            unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
        out.extend_from_slice(bytes);
    }
    #[cfg(target_endian = "big")]
    {
        out.extend(data.iter().flat_map(|v| v.to_le_bytes()));
    }
    out
}

/// The identity codec: f32 little-endian.
pub struct F32Codec;

impl WireCodec for F32Codec {
    fn id(&self) -> CodecId {
        CodecId::F32
    }

    fn encode(&self, cur: &[f32], _base: Option<&[f32]>) -> Vec<u8> {
        encode_f32_slice_le(cur)
    }

    fn decode_into(&self, bytes: &[u8], _base: Option<&[f32]>, dst: &mut [f32]) {
        assert_eq!(bytes.len(), dst.len() * 4, "f32 codec span mismatch");
        for (c, d) in bytes.chunks_exact(4).zip(dst.iter_mut()) {
            *d = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
        }
    }
}

/// bf16 truncation codec: 2 bytes/element, widened to f32 on decode so
/// every accumulation stays full precision.
pub struct Bf16Codec;

impl WireCodec for Bf16Codec {
    fn id(&self) -> CodecId {
        CodecId::Bf16
    }

    fn encode(&self, cur: &[f32], _base: Option<&[f32]>) -> Vec<u8> {
        let mut out = Vec::with_capacity(cur.len() * 2);
        for v in cur {
            out.extend(f32_to_bf16_bits(*v).to_le_bytes());
        }
        out
    }

    fn decode_into(&self, bytes: &[u8], _base: Option<&[f32]>, dst: &mut [f32]) {
        assert_eq!(bytes.len(), dst.len() * 2, "bf16 codec span mismatch");
        for (c, d) in bytes.chunks_exact(2).zip(dst.iter_mut()) {
            *d = bf16_bits_to_f32(u16::from_le_bytes([c[0], c[1]]));
        }
    }
}

/// XOR-delta codec: wire bytes are `cur.to_bits() ^ base.to_bits()`,
/// little-endian. Lossless, and all-zero wherever the model did not
/// move against the shared base.
pub struct DeltaCodec;

impl WireCodec for DeltaCodec {
    fn id(&self) -> CodecId {
        CodecId::Delta
    }

    fn encode(&self, cur: &[f32], base: Option<&[f32]>) -> Vec<u8> {
        let base = base.expect("delta codec encode requires a base span");
        assert_eq!(cur.len(), base.len(), "delta codec base length mismatch");
        let mut out = Vec::with_capacity(cur.len() * 4);
        for (c, b) in cur.iter().zip(base) {
            out.extend((c.to_bits() ^ b.to_bits()).to_le_bytes());
        }
        out
    }

    fn decode_into(&self, bytes: &[u8], base: Option<&[f32]>, dst: &mut [f32]) {
        let base = base.expect("delta codec decode requires a base span");
        assert_eq!(bytes.len(), dst.len() * 4, "delta codec span mismatch");
        assert_eq!(base.len(), dst.len(), "delta codec base length mismatch");
        for ((c, b), d) in bytes.chunks_exact(4).zip(base).zip(dst.iter_mut()) {
            let wire = u32::from_le_bytes([c[0], c[1], c[2], c[3]]);
            *d = f32::from_bits(wire ^ b.to_bits());
        }
    }
}

// ---- delta-rle: entropy-coded residual frames --------------------------

/// Frame flag: payload is `n × 4` raw little-endian XOR-residual bytes
/// (the escape for payloads compression would expand).
const FRAME_RAW: u8 = 0;
/// Frame flag: payload is the residual's 4 byte planes (LSB plane
/// first), each zero-run-length coded.
const FRAME_RLE: u8 = 1;

/// Cap on a frame's announced element count (hostile-input guard; real
/// frames cover at most one chunk's block).
const MAX_FRAME_ELEMS: u64 = 1 << 40;

/// LEB128 varint used inside delta-rle frames (self-contained so the
/// tensor layer stays independent of the proto wire helpers).
fn put_varint(out: &mut Vec<u8>, mut v: u64) {
    loop {
        let b = (v & 0x7F) as u8;
        v >>= 7;
        if v == 0 {
            out.push(b);
            return;
        }
        out.push(b | 0x80);
    }
}

fn get_varint(bytes: &[u8], pos: &mut usize) -> Result<u64> {
    let mut v = 0u64;
    let mut shift = 0u32;
    loop {
        let b = *bytes
            .get(*pos)
            .ok_or_else(|| anyhow::anyhow!("truncated varint in delta-rle frame"))?;
        *pos += 1;
        v |= u64::from(b & 0x7F) << shift;
        if b & 0x80 == 0 {
            return Ok(v);
        }
        shift += 7;
        if shift >= 64 {
            bail!("varint overflow in delta-rle frame");
        }
    }
}

/// The entropy-coded delta wire: byte-shuffle + zero-run encoding.
///
/// Each frame covers one contiguous element block and is self-
/// delimiting:
///
/// ```text
/// frame   := flag:u8  n:varint  payload
/// flag 1  := payload is 4 byte planes of (cur ^ base) bit patterns,
///            plane b = byte b of each little-endian residual word,
///            LSB plane first; each plane is a sequence of
///            (zero_run:varint, literal_run:varint, literal bytes…)
///            pairs until n bytes are produced
/// flag 0  := payload is n × 4 raw little-endian residual bytes — the
///            escape taken whenever the RLE form would reach raw size
/// ```
///
/// The shuffle groups each element's sign/exponent byte (and the high
/// mantissa byte) into contiguous planes: a model that moved little
/// since the shared base leaves those planes almost entirely zero, so
/// the zero-run coder collapses them to a handful of bytes. Wholly
/// random residuals take the escape, bounding every frame at raw size
/// plus the ≤ 7-byte header. Encoding and decoding are scratch-free:
/// planes are extracted/accumulated with shifted bit ops directly
/// against the element buffers.
pub struct DeltaRleCodec;

impl DeltaRleCodec {
    #[inline]
    fn residual_byte(cur: &[f32], base: &[f32], i: usize, plane: u32) -> u8 {
        (((cur[i].to_bits() ^ base[i].to_bits()) >> (8 * plane)) & 0xFF) as u8
    }
}

impl WireCodec for DeltaRleCodec {
    fn id(&self) -> CodecId {
        CodecId::DeltaRle
    }

    fn is_framed(&self) -> bool {
        true
    }

    fn encode(&self, cur: &[f32], base: Option<&[f32]>) -> Vec<u8> {
        let mut out = Vec::with_capacity(cur.len() + 16);
        self.encode_frame_into(cur, base, &mut out);
        out
    }

    fn encode_frame_into(&self, cur: &[f32], base: Option<&[f32]>, out: &mut Vec<u8>) {
        let base = base.expect("delta-rle codec encode requires a base span");
        assert_eq!(cur.len(), base.len(), "delta-rle codec base length mismatch");
        let n = cur.len();
        let start = out.len();
        out.push(FRAME_RLE);
        put_varint(out, n as u64);
        let payload_start = out.len();
        // The escape budget: the moment the RLE payload reaches raw
        // size, compression has lost and we rewrite the frame as raw.
        let budget = payload_start + n * 4;
        let mut fits = true;
        // Each plane recomputes the residual byte on the fly (twice at
        // run boundaries) instead of materializing the XOR words: the
        // recompute is cheap ALU on cached data, and it keeps the
        // encoder scratch-free — the property the zero-alloc steady
        // state relies on.
        'planes: for plane in 0..4u32 {
            let mut i = 0usize;
            while i < n {
                let zero_start = i;
                while i < n && Self::residual_byte(cur, base, i, plane) == 0 {
                    i += 1;
                }
                let lit_start = i;
                while i < n && Self::residual_byte(cur, base, i, plane) != 0 {
                    i += 1;
                }
                put_varint(out, (lit_start - zero_start) as u64);
                put_varint(out, (i - lit_start) as u64);
                for k in lit_start..i {
                    out.push(Self::residual_byte(cur, base, k, plane));
                }
                if out.len() >= budget {
                    fits = false;
                    break 'planes;
                }
            }
        }
        if !fits {
            out.truncate(start);
            out.push(FRAME_RAW);
            put_varint(out, n as u64);
            for (c, b) in cur.iter().zip(base) {
                out.extend((c.to_bits() ^ b.to_bits()).to_le_bytes());
            }
        }
    }

    fn frame_elems(&self, bytes: &[u8]) -> Result<usize> {
        let flag = *bytes
            .first()
            .ok_or_else(|| anyhow::anyhow!("empty delta-rle frame"))?;
        if flag != FRAME_RAW && flag != FRAME_RLE {
            bail!("unknown delta-rle frame flag {flag}");
        }
        let mut pos = 1usize;
        let n = get_varint(bytes, &mut pos)?;
        if n > MAX_FRAME_ELEMS {
            bail!("implausible delta-rle frame length {n}");
        }
        Ok(n as usize)
    }

    fn decode_frame(&self, bytes: &[u8], base: Option<&[f32]>, dst: &mut [f32]) -> Result<()> {
        let base = match base {
            Some(b) => b,
            None => bail!("delta-rle codec decode requires a base span"),
        };
        if base.len() != dst.len() {
            bail!("delta-rle codec base length mismatch");
        }
        let flag = *bytes
            .first()
            .ok_or_else(|| anyhow::anyhow!("empty delta-rle frame"))?;
        let mut pos = 1usize;
        let n = get_varint(bytes, &mut pos)? as usize;
        if n != dst.len() {
            bail!("delta-rle frame covers {n} elements, expected {}", dst.len());
        }
        match flag {
            FRAME_RAW => {
                if bytes.len() - pos != n * 4 {
                    bail!(
                        "delta-rle raw frame: {} payload bytes for {n} elements",
                        bytes.len() - pos
                    );
                }
                for ((c, b), d) in bytes[pos..].chunks_exact(4).zip(base).zip(dst.iter_mut()) {
                    let wire = u32::from_le_bytes([c[0], c[1], c[2], c[3]]);
                    *d = f32::from_bits(wire ^ b.to_bits());
                }
            }
            FRAME_RLE => {
                // Accumulate residual words in-place (dst doubles as the
                // u32 accumulator via to_bits/from_bits — no scratch).
                for d in dst.iter_mut() {
                    *d = f32::from_bits(0);
                }
                for plane in 0..4u32 {
                    let mut i = 0usize;
                    while i < n {
                        let zeros = get_varint(bytes, &mut pos)? as usize;
                        let lits = get_varint(bytes, &mut pos)? as usize;
                        if zeros == 0 && lits == 0 {
                            bail!("empty delta-rle run pair");
                        }
                        i = match i.checked_add(zeros) {
                            Some(x) if x <= n => x,
                            _ => bail!("delta-rle zero run overflows plane"),
                        };
                        if lits > n - i {
                            bail!("delta-rle literal run overflows plane");
                        }
                        if bytes.len() - pos < lits {
                            bail!("delta-rle frame truncated mid-literal-run");
                        }
                        for _ in 0..lits {
                            let b = bytes[pos];
                            pos += 1;
                            dst[i] =
                                f32::from_bits(dst[i].to_bits() | (u32::from(b) << (8 * plane)));
                            i += 1;
                        }
                    }
                }
                if pos != bytes.len() {
                    bail!("trailing bytes after delta-rle frame");
                }
                for (d, b) in dst.iter_mut().zip(base) {
                    *d = f32::from_bits(d.to_bits() ^ b.to_bits());
                }
            }
            other => bail!("unknown delta-rle frame flag {other}"),
        }
        Ok(())
    }

    fn decode_into(&self, bytes: &[u8], base: Option<&[f32]>, dst: &mut [f32]) {
        self.decode_frame(bytes, base, dst).expect("invalid delta-rle frame");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::prop_check;

    fn gaussian(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = crate::util::Rng::new(seed);
        (0..n).map(|_| rng.next_gaussian() as f32).collect()
    }

    #[test]
    fn codec_id_roundtrips_and_names() {
        for id in CodecId::ALL {
            assert_eq!(CodecId::from_code(id.code()).unwrap(), id);
            assert!(!id.name().is_empty());
            assert_eq!(id.codec().id(), id);
            assert_eq!(id.codec().is_framed(), id.is_framed());
        }
        assert!(CodecId::from_code(99).is_err());
        assert!(CodecId::F32.is_lossless() && CodecId::Delta.is_lossless());
        assert!(CodecId::DeltaRle.is_lossless());
        assert!(!CodecId::Bf16.is_lossless());
        assert!(CodecId::Delta.needs_base() && CodecId::DeltaRle.needs_base());
        assert!(CodecId::DeltaRle.is_framed() && !CodecId::Delta.is_framed());
        assert_eq!(CodecId::Bf16.wire_dtype(), DType::Bf16);
        assert_eq!(CodecId::DeltaRle.wire_dtype(), DType::F32);
    }

    #[test]
    fn negotiate_preserves_our_order_and_intersects() {
        let accepted = negotiate(
            &[CodecId::Delta, CodecId::F32],
            &[CodecId::F32, CodecId::Bf16, CodecId::Delta],
        );
        assert_eq!(accepted, vec![CodecId::F32, CodecId::Delta]);
        assert!(negotiate(&[], &CodecId::ALL).is_empty());
    }

    #[test]
    fn degrade_walks_the_lossless_chain() {
        let all = CodecId::ALL;
        assert_eq!(CodecId::DeltaRle.degrade_to(&all), CodecId::DeltaRle);
        assert_eq!(
            CodecId::DeltaRle.degrade_to(&[CodecId::F32, CodecId::Delta]),
            CodecId::Delta
        );
        assert_eq!(CodecId::DeltaRle.degrade_to(&[CodecId::F32]), CodecId::F32);
        assert_eq!(CodecId::Delta.degrade_to(&[CodecId::F32]), CodecId::F32);
        assert_eq!(CodecId::Bf16.degrade_to(&[CodecId::F32]), CodecId::F32);
        // Even an empty (legacy) set floors at f32.
        assert_eq!(CodecId::DeltaRle.degrade_to(&[]), CodecId::F32);
    }

    #[test]
    fn f32_and_delta_roundtrip_bitwise() {
        let cur = gaussian(257, 1);
        let base = gaussian(257, 2);
        // f32: no base.
        let enc = F32Codec.encode(&cur, None);
        let mut dst = vec![0.0f32; cur.len()];
        F32Codec.decode_into(&enc, None, &mut dst);
        for (a, b) in cur.iter().zip(&dst) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // delta: against a base.
        let enc = DeltaCodec.encode(&cur, Some(&base));
        let mut dst = vec![0.0f32; cur.len()];
        DeltaCodec.decode_into(&enc, Some(&base), &mut dst);
        for (a, b) in cur.iter().zip(&dst) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn delta_against_identical_base_is_all_zero_bytes() {
        let cur = gaussian(64, 3);
        let enc = DeltaCodec.encode(&cur, Some(&cur));
        assert!(enc.iter().all(|&b| b == 0));
    }

    #[test]
    fn bf16_error_bounded_by_mantissa() {
        // bf16 keeps 8 mantissa bits: relative error ≤ 2⁻⁸ for normal
        // values (round-to-nearest-even halves the ulp bound).
        let cur = gaussian(4096, 4);
        let enc = Bf16Codec.encode(&cur, None);
        assert_eq!(enc.len(), cur.len() * 2);
        let mut dst = vec![0.0f32; cur.len()];
        Bf16Codec.decode_into(&enc, None, &mut dst);
        for (a, b) in cur.iter().zip(&dst) {
            let bound = a.abs() * (1.0 / 256.0) + f32::MIN_POSITIVE;
            assert!((a - b).abs() <= bound, "a={a} b={b}");
        }
    }

    #[test]
    fn delta_rle_roundtrips_bitwise() {
        // Sparse, dense, and identical residual regimes all round-trip
        // bit for bit through the framed codec.
        let base = gaussian(513, 10);
        let mut sparse = base.clone();
        for v in sparse.iter_mut().step_by(23) {
            *v += 1e-4;
        }
        let dense = gaussian(513, 11);
        let identical = base.clone();
        for cur in [&sparse, &dense, &identical] {
            let enc = DeltaRleCodec.encode(cur, Some(&base));
            assert_eq!(DeltaRleCodec.frame_elems(&enc).unwrap(), cur.len());
            let mut dst = vec![0.0f32; cur.len()];
            DeltaRleCodec.decode_frame(&enc, Some(&base), &mut dst).unwrap();
            for (a, b) in cur.iter().zip(&dst) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }

    #[test]
    fn delta_rle_all_zero_residual_collapses() {
        let cur = gaussian(4096, 12);
        let enc = DeltaRleCodec.encode(&cur, Some(&cur));
        // Four planes of one (zeros=n, lits=0) pair each + header.
        assert!(enc.len() < 64, "all-zero residual encoded to {} bytes", enc.len());
        assert_eq!(enc[0], FRAME_RLE);
    }

    #[test]
    fn delta_rle_adversarial_payload_escapes_to_raw() {
        // Random cur vs random base: every residual byte is noise, so
        // compression must escape and the frame stays ≤ raw + header.
        let cur = gaussian(777, 13);
        let base = gaussian(777, 14);
        let enc = DeltaRleCodec.encode(&cur, Some(&base));
        assert_eq!(enc[0], FRAME_RAW);
        assert!(enc.len() <= 777 * 4 + 7, "frame expanded to {} bytes", enc.len());
        let mut dst = vec![0.0f32; 777];
        DeltaRleCodec.decode_frame(&enc, Some(&base), &mut dst).unwrap();
        for (a, b) in cur.iter().zip(&dst) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn delta_rle_small_updates_compress_below_half() {
        // The steady-state regime the codec targets: every element moved
        // a little, so the sign/exponent and high-mantissa planes are
        // almost all zero.
        let base = gaussian(4096, 15);
        let cur: Vec<f32> = base.iter().map(|v| v * (1.0 + 1e-5)).collect();
        let enc = DeltaRleCodec.encode(&cur, Some(&base));
        assert!(
            enc.len() * 2 <= 4096 * 4,
            "small-update frame is {} bytes of {} raw",
            enc.len(),
            4096 * 4
        );
    }

    #[test]
    fn delta_rle_rejects_malformed_frames() {
        let cur = gaussian(32, 16);
        let base = gaussian(32, 17);
        let enc = DeltaRleCodec.encode(&cur, Some(&base));
        let mut dst = vec![0.0f32; 32];
        // Truncated payload.
        let err = DeltaRleCodec.decode_frame(&enc[..enc.len() - 3], Some(&base), &mut dst);
        assert!(err.is_err());
        // Unknown flag byte.
        let mut bad = enc.clone();
        bad[0] = 9;
        assert!(DeltaRleCodec.decode_frame(&bad, Some(&base), &mut dst).is_err());
        assert!(DeltaRleCodec.frame_elems(&bad).is_err());
        // Trailing garbage.
        let mut bad = enc.clone();
        bad.push(0);
        assert!(DeltaRleCodec.decode_frame(&bad, Some(&base), &mut dst).is_err());
        // Element-count mismatch against the destination span.
        assert!(DeltaRleCodec.decode_frame(&enc, Some(&base[..31]), &mut dst[..31]).is_err());
        // Missing base.
        assert!(DeltaRleCodec.decode_frame(&enc, None, &mut dst).is_err());
        assert!(DeltaRleCodec.frame_elems(&[]).is_err());
    }

    #[test]
    fn delta_rle_prop_roundtrip_and_size_bound() {
        prop_check("delta-rle frame roundtrip", 80, |g| {
            let n = g.usize_in(1..600);
            let base = gaussian(n, g.rng().next_u64());
            let mut cur = base.clone();
            // Perturb a g-chosen fraction at a g-chosen magnitude: the
            // sparse→dense sweep covers both RLE and escape regimes.
            let frac = g.usize_in(1..101);
            let scale = [1e-6f32, 1e-3, 1.0][g.usize_in(0..3)];
            for v in cur.iter_mut() {
                if g.usize_in(0..100) < frac {
                    *v += scale * g.f32_in(-0.5, 0.5);
                }
            }
            let enc = DeltaRleCodec.encode(&cur, Some(&base));
            assert!(enc.len() <= n * 4 + 7, "frame for n={n} expanded to {}", enc.len());
            assert_eq!(DeltaRleCodec.frame_elems(&enc).unwrap(), n);
            let mut dst = vec![0.0f32; n];
            DeltaRleCodec.decode_frame(&enc, Some(&base), &mut dst).unwrap();
            for (a, b) in cur.iter().zip(&dst) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        });
    }

    #[test]
    fn prop_split_point_independent_decode() {
        // Decoding a codec's bytes span-wise at any element split matches
        // the whole-buffer decode bit for bit — the property the chunked
        // stream receiver relies on. Framed codecs are exempt (frames
        // are never split on the wire; block independence is covered by
        // `delta_rle_prop_roundtrip_and_size_bound` + the ingest tests).
        prop_check("codec split decode", 60, |g| {
            let n = g.usize_in(1..300);
            let cur = gaussian(n, g.rng().next_u64());
            let base = gaussian(n, g.rng().next_u64());
            for id in CodecId::ALL {
                if id.is_framed() {
                    continue;
                }
                let c = id.codec();
                let b = id.needs_base().then_some(&base[..]);
                let enc = c.encode(&cur, b);
                let esz = id.wire_dtype().size_bytes();
                let mut whole = vec![0.0f32; n];
                c.decode_into(&enc, b, &mut whole);
                let split = g.usize_in(0..n + 1);
                let mut parts = vec![0.0f32; n];
                c.decode_into(&enc[..split * esz], b.map(|s| &s[..split]), &mut parts[..split]);
                c.decode_into(&enc[split * esz..], b.map(|s| &s[split..]), &mut parts[split..]);
                for (a, p) in whole.iter().zip(&parts) {
                    assert_eq!(a.to_bits(), p.to_bits(), "{id}");
                }
            }
        });
    }
}
