//! Negotiable wire codecs for the model data plane.
//!
//! The data plane moves tensor payloads as raw byte chunks; a *codec*
//! decides what those bytes are. Three codecs are spoken today, offered
//! and accepted in the `Hello`/`HelloAck` handshake and carried per
//! stream by `ModelStreamBegin`:
//!
//! * [`CodecId::F32`] — today's tensor-as-bytes path: 4 bytes/element,
//!   little-endian, bitwise lossless (the §3 baseline).
//! * [`CodecId::Bf16`] — half-precision truncation (round-to-nearest-even
//!   bf16), 2 bytes/element. Lossy: the receiver widens back to f32 on
//!   decode and every downstream accumulation stays f32/f64, so only the
//!   wire pays the precision cut. Error is bounded by bf16's 8 mantissa
//!   bits (≤ 2⁻⁸ relative per element, property-tested).
//! * [`CodecId::Delta`] — XOR of the current f32 bit pattern against a
//!   **base model both peers hold** (the last community model the peer
//!   acknowledged). 4 bytes/element, bitwise lossless, and the bytes are
//!   overwhelmingly zero when the model moved little — the stream is
//!   cheap to squeeze with any byte-level compressor and cheap to
//!   checksum. Requires a shared base; senders fall back to full `F32`
//!   when no base is shared (new learner, stale round, async staleness).
//!
//! Codecs are *element-size-stable*: encoded length is
//! `elems × wire_dtype().size_bytes()`, which is what lets the chunked
//! stream receiver pre-size its decode buffers from the announced layout
//! before any payload byte arrives.

use super::{bf16_bits_to_f32, f32_to_bf16_bits, DType};
use anyhow::{bail, Result};

/// Identity of a wire codec (negotiated in `Hello`, carried per stream).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CodecId {
    /// f32 little-endian tensor-as-bytes (lossless, no base).
    F32,
    /// bf16 truncation, f32 widen on decode (lossy, no base).
    Bf16,
    /// f32 bit-XOR against a shared base model (lossless, needs base).
    Delta,
}

impl CodecId {
    /// Every codec this build speaks, in preference order for `auto`
    /// resolution (lossless-and-small first).
    pub const ALL: [CodecId; 3] = [CodecId::F32, CodecId::Bf16, CodecId::Delta];

    pub fn code(self) -> u8 {
        match self {
            CodecId::F32 => 0,
            CodecId::Bf16 => 1,
            CodecId::Delta => 2,
        }
    }

    pub fn from_code(c: u8) -> Result<CodecId> {
        Ok(match c {
            0 => CodecId::F32,
            1 => CodecId::Bf16,
            2 => CodecId::Delta,
            _ => bail!("unknown wire codec code {c}"),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            CodecId::F32 => "f32",
            CodecId::Bf16 => "bf16",
            CodecId::Delta => "delta",
        }
    }

    /// Does a decode round-trip reproduce the input bit for bit?
    pub fn is_lossless(self) -> bool {
        !matches!(self, CodecId::Bf16)
    }

    /// Does this codec need a shared base model on both ends?
    pub fn needs_base(self) -> bool {
        matches!(self, CodecId::Delta)
    }

    /// Element type the encoded bytes are sized as on the wire (the
    /// dtype a stream's `TensorLayoutProto` announces for this codec).
    pub fn wire_dtype(self) -> DType {
        match self {
            CodecId::Bf16 => DType::Bf16,
            CodecId::F32 | CodecId::Delta => DType::F32,
        }
    }

    /// Static codec implementation for this id.
    pub fn codec(self) -> &'static dyn WireCodec {
        match self {
            CodecId::F32 => &F32Codec,
            CodecId::Bf16 => &Bf16Codec,
            CodecId::Delta => &DeltaCodec,
        }
    }
}

impl std::fmt::Display for CodecId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Intersection of an offered codec set with ours, preserving `ours`'s
/// order — the accept set a `HelloAck` carries.
pub fn negotiate(offered: &[CodecId], ours: &[CodecId]) -> Vec<CodecId> {
    ours.iter().copied().filter(|c| offered.contains(c)).collect()
}

/// One wire codec: element bytes in, element bytes out.
///
/// `base` is the shared base model's elements aligned with `cur`/`dst`
/// (same tensor, same local element range); it MUST be `Some` with a
/// matching length for [`CodecId::Delta`] and is ignored otherwise.
/// Encoded bytes are little-endian regardless of host order.
pub trait WireCodec: Send + Sync {
    fn id(&self) -> CodecId;

    /// Encode `cur` into wire bytes (`cur.len() × wire_dtype` bytes).
    fn encode(&self, cur: &[f32], base: Option<&[f32]>) -> Vec<u8>;

    /// Decode a whole-element span of wire bytes into `dst`.
    /// `bytes.len()` must equal `dst.len() × wire_dtype` bytes.
    fn decode_into(&self, bytes: &[u8], base: Option<&[f32]>, dst: &mut [f32]);
}

/// Encode an f32 slice as little-endian bytes — the §3 flatten-and-dump
/// hot path (one memcpy on little-endian hosts), shared by
/// `Tensor::encode_data` and the wire codecs.
pub fn encode_f32_slice_le(data: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(data.len() * 4);
    #[cfg(target_endian = "little")]
    {
        // SAFETY: f32 has no invalid bit patterns; the slice covers
        // exactly the initialized storage.
        let bytes =
            unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
        out.extend_from_slice(bytes);
    }
    #[cfg(target_endian = "big")]
    {
        out.extend(data.iter().flat_map(|v| v.to_le_bytes()));
    }
    out
}

/// The identity codec: f32 little-endian.
pub struct F32Codec;

impl WireCodec for F32Codec {
    fn id(&self) -> CodecId {
        CodecId::F32
    }

    fn encode(&self, cur: &[f32], _base: Option<&[f32]>) -> Vec<u8> {
        encode_f32_slice_le(cur)
    }

    fn decode_into(&self, bytes: &[u8], _base: Option<&[f32]>, dst: &mut [f32]) {
        assert_eq!(bytes.len(), dst.len() * 4, "f32 codec span mismatch");
        for (c, d) in bytes.chunks_exact(4).zip(dst.iter_mut()) {
            *d = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
        }
    }
}

/// bf16 truncation codec: 2 bytes/element, widened to f32 on decode so
/// every accumulation stays full precision.
pub struct Bf16Codec;

impl WireCodec for Bf16Codec {
    fn id(&self) -> CodecId {
        CodecId::Bf16
    }

    fn encode(&self, cur: &[f32], _base: Option<&[f32]>) -> Vec<u8> {
        let mut out = Vec::with_capacity(cur.len() * 2);
        for v in cur {
            out.extend(f32_to_bf16_bits(*v).to_le_bytes());
        }
        out
    }

    fn decode_into(&self, bytes: &[u8], _base: Option<&[f32]>, dst: &mut [f32]) {
        assert_eq!(bytes.len(), dst.len() * 2, "bf16 codec span mismatch");
        for (c, d) in bytes.chunks_exact(2).zip(dst.iter_mut()) {
            *d = bf16_bits_to_f32(u16::from_le_bytes([c[0], c[1]]));
        }
    }
}

/// XOR-delta codec: wire bytes are `cur.to_bits() ^ base.to_bits()`,
/// little-endian. Lossless, and all-zero wherever the model did not
/// move against the shared base.
pub struct DeltaCodec;

impl WireCodec for DeltaCodec {
    fn id(&self) -> CodecId {
        CodecId::Delta
    }

    fn encode(&self, cur: &[f32], base: Option<&[f32]>) -> Vec<u8> {
        let base = base.expect("delta codec encode requires a base span");
        assert_eq!(cur.len(), base.len(), "delta codec base length mismatch");
        let mut out = Vec::with_capacity(cur.len() * 4);
        for (c, b) in cur.iter().zip(base) {
            out.extend((c.to_bits() ^ b.to_bits()).to_le_bytes());
        }
        out
    }

    fn decode_into(&self, bytes: &[u8], base: Option<&[f32]>, dst: &mut [f32]) {
        let base = base.expect("delta codec decode requires a base span");
        assert_eq!(bytes.len(), dst.len() * 4, "delta codec span mismatch");
        assert_eq!(base.len(), dst.len(), "delta codec base length mismatch");
        for ((c, b), d) in bytes.chunks_exact(4).zip(base).zip(dst.iter_mut()) {
            let wire = u32::from_le_bytes([c[0], c[1], c[2], c[3]]);
            *d = f32::from_bits(wire ^ b.to_bits());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::prop_check;

    fn gaussian(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = crate::util::Rng::new(seed);
        (0..n).map(|_| rng.next_gaussian() as f32).collect()
    }

    #[test]
    fn codec_id_roundtrips_and_names() {
        for id in CodecId::ALL {
            assert_eq!(CodecId::from_code(id.code()).unwrap(), id);
            assert!(!id.name().is_empty());
            assert_eq!(id.codec().id(), id);
        }
        assert!(CodecId::from_code(99).is_err());
        assert!(CodecId::F32.is_lossless() && CodecId::Delta.is_lossless());
        assert!(!CodecId::Bf16.is_lossless());
        assert!(CodecId::Delta.needs_base());
        assert_eq!(CodecId::Bf16.wire_dtype(), DType::Bf16);
    }

    #[test]
    fn negotiate_preserves_our_order_and_intersects() {
        let accepted = negotiate(
            &[CodecId::Delta, CodecId::F32],
            &[CodecId::F32, CodecId::Bf16, CodecId::Delta],
        );
        assert_eq!(accepted, vec![CodecId::F32, CodecId::Delta]);
        assert!(negotiate(&[], &CodecId::ALL).is_empty());
    }

    #[test]
    fn f32_and_delta_roundtrip_bitwise() {
        let cur = gaussian(257, 1);
        let base = gaussian(257, 2);
        // f32: no base.
        let enc = F32Codec.encode(&cur, None);
        let mut dst = vec![0.0f32; cur.len()];
        F32Codec.decode_into(&enc, None, &mut dst);
        for (a, b) in cur.iter().zip(&dst) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // delta: against a base.
        let enc = DeltaCodec.encode(&cur, Some(&base));
        let mut dst = vec![0.0f32; cur.len()];
        DeltaCodec.decode_into(&enc, Some(&base), &mut dst);
        for (a, b) in cur.iter().zip(&dst) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn delta_against_identical_base_is_all_zero_bytes() {
        let cur = gaussian(64, 3);
        let enc = DeltaCodec.encode(&cur, Some(&cur));
        assert!(enc.iter().all(|&b| b == 0));
    }

    #[test]
    fn bf16_error_bounded_by_mantissa() {
        // bf16 keeps 8 mantissa bits: relative error ≤ 2⁻⁸ for normal
        // values (round-to-nearest-even halves the ulp bound).
        let cur = gaussian(4096, 4);
        let enc = Bf16Codec.encode(&cur, None);
        assert_eq!(enc.len(), cur.len() * 2);
        let mut dst = vec![0.0f32; cur.len()];
        Bf16Codec.decode_into(&enc, None, &mut dst);
        for (a, b) in cur.iter().zip(&dst) {
            let bound = a.abs() * (1.0 / 256.0) + f32::MIN_POSITIVE;
            assert!((a - b).abs() <= bound, "a={a} b={b}");
        }
    }

    #[test]
    fn prop_split_point_independent_decode() {
        // Decoding a codec's bytes span-wise at any element split matches
        // the whole-buffer decode bit for bit — the property the chunked
        // stream receiver relies on.
        prop_check("codec split decode", 60, |g| {
            let n = g.usize_in(1..300);
            let cur = gaussian(n, g.rng().next_u64());
            let base = gaussian(n, g.rng().next_u64());
            for id in CodecId::ALL {
                let c = id.codec();
                let b = id.needs_base().then_some(&base[..]);
                let enc = c.encode(&cur, b);
                let esz = id.wire_dtype().size_bytes();
                let mut whole = vec![0.0f32; n];
                c.decode_into(&enc, b, &mut whole);
                let split = g.usize_in(0..n + 1);
                let mut parts = vec![0.0f32; n];
                c.decode_into(&enc[..split * esz], b.map(|s| &s[..split]), &mut parts[..split]);
                c.decode_into(&enc[split * esz..], b.map(|s| &s[split..]), &mut parts[split..]);
                for (a, p) in whole.iter().zip(&parts) {
                    assert_eq!(a.to_bits(), p.to_bits(), "{id}");
                }
            }
        });
    }
}
