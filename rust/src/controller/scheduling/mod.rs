//! Round schedulers: synchronous, semi-synchronous, asynchronous.
//!
//! The paper's Table 1 highlights protocol support as a MetisFL
//! differentiator: synchronous (plus the semi-synchronous variant of
//! Stripelis et al. 2022b) and asynchronous execution. Each scheduler
//! drives the controller through the Fig.-1 timeline and fills a
//! [`RoundReport`] with the per-operation timings the evaluation plots.

pub mod asynchronous;
pub mod semi_sync;
pub mod sync;

pub use asynchronous::run_async_session;
pub use semi_sync::run_semi_sync_round;
pub use sync::run_sync_round;

use super::Controller;
use crate::config::Protocol;
use crate::metrics::RoundReport;
use crate::util::Rng;
use anyhow::Result;

/// Dispatch to the protocol configured in the controller's env.
///
/// For sync / semi-sync this runs exactly one federation round. For the
/// async protocol one "round" is defined (as in the paper's community
/// update requests) as `learners` community updates; see
/// [`run_async_session`] to drive the whole session at once.
pub fn run_round(ctrl: &Controller, round: u64, rng: &mut Rng) -> Result<RoundReport> {
    match ctrl.env.protocol {
        Protocol::Synchronous => run_sync_round(ctrl, round, rng),
        Protocol::SemiSynchronous { lambda } => run_semi_sync_round(ctrl, round, lambda, rng),
        Protocol::Asynchronous { .. } => {
            let mut reports = run_async_session(ctrl, 1, rng)?;
            Ok(reports.remove(0))
        }
    }
}
