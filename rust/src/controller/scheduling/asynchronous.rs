//! Asynchronous protocol (Table 1's MetisFL-only row).
//!
//! No round barrier: the controller dispatches a training task to every
//! learner once; whenever a learner finishes, `MarkTaskCompleted`
//! immediately mixes its model into the community model (discounted by
//! staleness — see [`Controller::async_mix`]) and the scheduler hands
//! that learner a fresh task against the updated community model.
//!
//! The paper reports async progress in "community update requests"; we
//! group `learners` consecutive community updates into one
//! [`RoundReport`] so async sessions remain comparable to sync rounds.
//!
//! With `stream_chunk_bytes > 0` the async session rides the same
//! codec-aware data plane as sync rounds: the initial fan-out is one
//! encode-once chunk stream shared by every learner, and each
//! re-dispatch is a single-target stream delta-coded against the last
//! model *that* learner acknowledged (per-learner base map — async
//! learners sit at divergent community rounds, so no single shared base
//! can serve them).

use super::super::Controller;
use crate::metrics::{FedOp, RoundReport};
use crate::proto::client;
use crate::proto::{Message, ModelProto, StreamPurpose, TaskSpec};
use crate::tensor::{ByteOrder, DType};
use crate::util::{log_warn, Rng, Stopwatch};
use anyhow::{bail, Result};
use std::time::Duration;

/// Drive an async session producing `rounds` reports (each covering
/// `learners` community updates).
pub fn run_async_session(
    ctrl: &Controller,
    rounds: usize,
    rng: &mut Rng,
) -> Result<Vec<RoundReport>> {
    let participants = ctrl.select_participants(rng);
    if participants.is_empty() {
        bail!("async session: no registered learners");
    }
    let n = participants.len();
    let spec = TaskSpec {
        epochs: ctrl.env.local_epochs,
        batch_size: ctrl.env.batch_size,
        learning_rate: ctrl.env.learning_rate,
        step_budget: 0,
    };

    let mut reports = Vec::with_capacity(rounds);
    let updates_target = (rounds * n) as u64;
    let start_updates = ctrl.async_updates();
    let mut dispatched_round: u64 = 0;

    // Initial fan-out: streamed (encode-once, codec-aware) when a chunk
    // size is configured, one-shot otherwise.
    let streamed = ctrl.env.effective_stream_chunk() > 0;
    let first_sw = Stopwatch::start_with(ctrl.clock());
    let (dispatch_time, acks) = {
        let (community, cround) = ctrl
            .community()
            .ok_or_else(|| anyhow::anyhow!("async session: community model not initialized"))?;
        if streamed {
            ctrl.stream_broadcast(
                &participants,
                StreamPurpose::RunTask,
                dispatched_round,
                &spec,
                None,
                &community,
                cround,
            )
        } else {
            let proto = ModelProto::from_model(&community, DType::F32, ByteOrder::Little);
            // Release the snapshot so async mixing can recycle the
            // model's buffers when it is replaced.
            drop(community);
            let initial_task = Message::RunTask {
                task_id: dispatched_round,
                round: dispatched_round,
                model: proto,
                spec: spec.clone(),
            };
            ctrl.broadcast(&participants, &initial_task)
        }
    };
    ctrl.record(FedOp::TrainDispatch, dispatch_time);
    let mut any_ok = false;
    for (id, a) in &acks {
        match a {
            Ok(reply) if client::ack_of(reply).is_ok() => {
                ctrl.mark_task_outstanding(id);
                any_ok = true;
            }
            Ok(reply) => {
                log_warn("async", &format!("{id}: dispatch rejected: {}", reply.kind()))
            }
            Err(e) => log_warn("async", &format!("{id}: dispatch failed: {e:#}")),
        }
    }
    if !any_ok {
        bail!("async session: every initial dispatch failed");
    }

    // Re-dispatch loop: poll completed counts; when a learner finishes,
    // its handle becomes idle. We track idleness via a per-learner
    // outstanding flag updated from completion deltas.
    let session_sw = Stopwatch::start_with(ctrl.clock());
    let session_budget =
        Duration::from_millis(ctrl.env.task_timeout_ms) * (rounds as u32 + 1);
    let mut report_sw = Stopwatch::start_with(ctrl.clock());
    let mut last_seen = start_updates;
    while ctrl.async_updates() - start_updates < updates_target {
        if session_sw.elapsed() > session_budget {
            log_warn("async", "session deadline exceeded; stopping early");
            break;
        }
        let updates = ctrl.async_updates();
        if updates > last_seen {
            // One or more learners completed; hand each a fresh task.
            // Identify idle learners as those whose dispatch_round is
            // behind the community round (set by async_mix).
            for h in &participants {
                let needs_task = ctrl.learner_needs_task(&h.id);
                if needs_task {
                    let (community, cround) = ctrl.community().unwrap();
                    dispatched_round = cround;
                    let sw = Stopwatch::start_with(ctrl.clock());
                    let r = if streamed {
                        // Single-target stream, delta-coded against the
                        // last model this learner acknowledged.
                        ctrl.stream_to_learner(
                            h,
                            StreamPurpose::RunTask,
                            dispatched_round,
                            &spec,
                            &community,
                            cround,
                        )
                    } else {
                        let proto =
                            ModelProto::from_model(&community, DType::F32, ByteOrder::Little);
                        drop(community);
                        h.rpc(
                            ctrl.psk,
                            &Message::RunTask {
                                task_id: dispatched_round,
                                round: dispatched_round,
                                model: proto,
                                spec: spec.clone(),
                            },
                        )
                    };
                    ctrl.record(FedOp::TrainDispatch, sw.elapsed());
                    match r {
                        Ok(reply) if client::ack_of(&reply).is_ok() => {
                            ctrl.mark_task_outstanding(&h.id)
                        }
                        Ok(reply) => log_warn(
                            "async",
                            &format!("{}: re-dispatch rejected: {}", h.id, reply.kind()),
                        ),
                        Err(e) => {
                            log_warn("async", &format!("{}: re-dispatch failed: {e:#}", h.id))
                        }
                    }
                }
            }
            last_seen = updates;
        } else {
            ctrl.clock().sleep(Duration::from_micros(200));
        }

        // Emit a report every `n` community updates.
        let done = ctrl.async_updates() - start_updates;
        while (reports.len() + 1) * n <= done as usize {
            let elapsed = report_sw.lap();
            let agg_mean = ctrl.metrics().mean(FedOp::Aggregation);
            reports.push(RoundReport {
                round: reports.len() as u64 + 1,
                participants: n,
                completed: n,
                community_eval_loss: None,
                train_dispatch: ctrl.metrics().mean(FedOp::TrainDispatch),
                train_round: elapsed,
                aggregation: agg_mean,
                eval_dispatch: Duration::ZERO,
                eval_round: Duration::ZERO,
                federation_round: elapsed,
                completion_spread: Duration::ZERO,
            });
            ctrl.record(FedOp::FederationRound, elapsed);
        }
    }
    if reports.is_empty() {
        bail!("async session produced no community updates");
    }
    while reports.len() < rounds {
        // Deadline hit: pad with the last observed cadence so callers see
        // how far the session got.
        let last = reports.last().unwrap().clone();
        reports.push(RoundReport { round: last.round + 1, completed: 0, ..last });
    }
    let _ = first_sw;
    Ok(reports)
}
