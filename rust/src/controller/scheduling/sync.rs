//! Synchronous FedAvg rounds (the paper's evaluation protocol, §4.2).
//!
//! Timeline (Fig. 1): select participants → dispatch train tasks
//! (async callbacks, acked) → barrier on `MarkTaskCompleted` → store /
//! select / aggregate → dispatch eval tasks → collect evaluations.

use super::super::Controller;
use crate::metrics::{FedOp, RoundReport};
use crate::obs::SpanCtx;
use crate::proto::client;
use crate::proto::{Message, ModelProto, StreamPurpose, TaskMeta, TaskSpec};
use crate::tensor::{ByteOrder, DType};
use crate::util::{log_debug, log_warn, Rng, Stopwatch};
use anyhow::{bail, Result};
use std::time::Duration;

pub fn run_sync_round(ctrl: &Controller, round: u64, rng: &mut Rng) -> Result<RoundReport> {
    run_round_with_budget(ctrl, round, 0, false, rng)
}

/// Shared implementation: `step_budget == 0` → plain sync (train by
/// epochs); `> 0` → semi-sync (train by step budget). With `paced` set,
/// the fixed budget becomes the *fallback* and each learner receives
/// its own budget from the pacing profiles (`λ·t_target·throughput_i`),
/// so a heterogeneous fleet finishes the round at the same wall clock.
pub(crate) fn run_round_with_budget(
    ctrl: &Controller,
    round: u64,
    step_budget: usize,
    paced: bool,
    rng: &mut Rng,
) -> Result<RoundReport> {
    let round_sw = Stopwatch::start_with(ctrl.clock());
    // Root span for the round. On a root controller this opens a fresh
    // trace; behind an aggregator it parents under the shard-round span
    // (`span_parent`), so the whole federation shares one trace.
    let round_span = ctrl.span_sink().begin("round", ctrl.span_parent()).round(round);
    ctrl.set_round_ctx(round_span.ctx());
    let participants = ctrl.select_participants(rng);
    if participants.is_empty() {
        bail!("round {round}: no registered learners");
    }
    let (community, community_round) = ctrl
        .community()
        .ok_or_else(|| anyhow::anyhow!("round {round}: community model not initialized"))?;
    let streamed = ctrl.env.effective_stream_chunk() > 0;

    let ids: Vec<String> = participants.iter().map(|h| h.id.clone()).collect();
    ctrl.open_round(round, &ids);

    // --- Train dispatch (RunTask, acked immediately; Fig. 9) ----------
    let spec = TaskSpec {
        epochs: ctrl.env.local_epochs,
        batch_size: ctrl.env.batch_size,
        learning_rate: ctrl.env.learning_rate,
        step_budget,
    };
    // Per-learner pacing budgets: profiled learners get
    // `t_target × throughput_i` (the slowest profiled learner anchors
    // t_target at the fixed budget), unseen learners keep the fixed
    // fallback. When nobody differs from the fallback (e.g. round 1,
    // no profiles yet) the round keeps the shared encode-once frame.
    let budgets: Option<Vec<usize>> = (paced && step_budget > 0)
        .then(|| ctrl.pacing().step_budgets(&ids, step_budget))
        .filter(|b| b.iter().any(|x| *x != step_budget));
    if let Some(b) = &budgets {
        log_debug(
            "scheduler",
            &format!("round {round}: paced step budgets {:?}", b),
        );
    }
    let train_sw = Stopwatch::start_with(ctrl.clock());
    let (dispatch_time, acks) = if streamed {
        // Symmetric data plane: the community model fans out as one
        // encode-once chunk stream shared by every learner, under the
        // negotiated wire codec (Serialization is recorded inside).
        ctrl.stream_broadcast(
            &participants,
            StreamPurpose::RunTask,
            round,
            &spec,
            budgets.as_deref(),
            &community,
            community_round,
        )
    } else if let Some(budgets) = &budgets {
        // Pacing-aware one-shot: every learner gets its own step
        // budget, but the model bytes are still serialized ONCE and
        // shared as the frame prefix (spec is the trailing wire field
        // of RunTask); full frames materialize per send inside the
        // dispatch pool.
        let ser_sw = Stopwatch::start_with(ctrl.clock());
        let model_proto = ModelProto::from_model(&community, DType::F32, ByteOrder::Little);
        let specs: Vec<TaskSpec> = budgets
            .iter()
            .map(|b| TaskSpec { step_budget: *b, ..spec.clone() })
            .collect();
        let (prefix, suffixes) =
            Message::encode_run_task_parts(round, round, &model_proto, &specs);
        ctrl.record(FedOp::Serialization, ser_sw.elapsed());
        ctrl.broadcast_prefixed(&participants, &prefix, &suffixes)
    } else {
        // One-shot: serialize the community model once per round
        // (tensor-as-bytes, §3) and fan the same frame out.
        let ser_sw = Stopwatch::start_with(ctrl.clock());
        let model_proto = ModelProto::from_model(&community, DType::F32, ByteOrder::Little);
        ctrl.record(FedOp::Serialization, ser_sw.elapsed());
        let run_task =
            Message::RunTask { task_id: round, round, model: model_proto, spec: spec.clone() };
        ctrl.broadcast(&participants, &run_task)
    };
    // Release the snapshot now that it's dispatched: aggregation replaces
    // the community model, and a sole-owner `Arc` at that point lets the
    // controller recycle its buffers into the scratch arena.
    drop(community);
    ctrl.record(FedOp::TrainDispatch, dispatch_time);
    let mut dispatched = 0usize;
    for (id, ack) in &acks {
        match ack {
            Ok(reply) => match client::ack_of(reply) {
                Ok(_) => dispatched += 1,
                Err(e) => log_warn("scheduler", &format!("{id}: dispatch rejected: {e}")),
            },
            Err(e) => log_warn("scheduler", &format!("{id}: train dispatch failed: {e:#}")),
        }
    }
    if dispatched == 0 {
        bail!("round {round}: every train dispatch failed");
    }

    // --- Training round barrier (T1–T4) -------------------------------
    // Classic rounds (quorum_fraction = 1) wait for everyone or the
    // timeout; deadline-quorum rounds aggregate as soon as the quorum
    // completed, reweighting by the actual participants — completions
    // that miss the cut fold through the async staleness path instead
    // of being dropped (see Controller::complete_task).
    let barrier_span = ctrl.span_sink().begin("barrier", round_span.ctx()).round(round);
    let outcome = ctrl.wait_round_quorum(
        Duration::from_millis(ctrl.env.task_timeout_ms),
        ctrl.env.quorum_fraction,
    );
    barrier_span.end();
    let arrived = outcome.arrived;
    let train_round_time = train_sw.elapsed();
    ctrl.record(FedOp::TrainRound, train_round_time);
    // Learners that were expected but missed the round feed the pacing
    // failure history (reliability decay → PacingAware deprioritizes
    // them).
    for id in &outcome.missing {
        ctrl.pacing().observe_failure(id);
    }
    if arrived.len() < dispatched {
        log_warn(
            "scheduler",
            &format!(
                "round {round}: {}/{} learners completed before {}",
                arrived.len(),
                dispatched,
                if ctrl.env.quorum_fraction < 1.0 { "the quorum cut" } else { "timeout" }
            ),
        );
    }
    if arrived.is_empty() {
        bail!("round {round}: no learner completed training");
    }

    // --- Aggregation (T4–T7) -------------------------------------------
    let agg_sw = Stopwatch::start_with(ctrl.clock());
    let new_model = ctrl.aggregate_from_store(&arrived, round)?;
    let aggregation_time = agg_sw.elapsed();
    ctrl.record(FedOp::Aggregation, aggregation_time);
    log_debug(
        "scheduler",
        &format!("round {round}: aggregated {} models in {:?}", arrived.len(), aggregation_time),
    );

    // --- Evaluation round (T7–T9, synchronous calls; Fig. 10) ----------
    let eval_sw = Stopwatch::start_with(ctrl.clock());
    let (eval_dispatch, replies) = if streamed {
        // The eval stream ships the freshly aggregated community model
        // (now at `round`); its `End` reply carries the evaluation. It
        // also refreshes every learner's delta base to the new model.
        ctrl.stream_broadcast(
            &participants,
            StreamPurpose::Evaluate,
            round,
            &TaskSpec::default(),
            None,
            &new_model,
            round,
        )
    } else {
        let ser_sw = Stopwatch::start_with(ctrl.clock());
        let eval_proto = ModelProto::from_model(&new_model, DType::F32, ByteOrder::Little);
        ctrl.record(FedOp::Serialization, ser_sw.elapsed());
        let eval_task = Message::EvaluateModel { task_id: round, round, model: eval_proto };
        ctrl.broadcast(&participants, &eval_task)
    };
    let eval_round_time = eval_sw.elapsed();
    ctrl.record(FedOp::EvalDispatch, eval_dispatch);
    ctrl.record(FedOp::EvalRound, eval_round_time);

    let mut weighted_loss = 0.0f64;
    let mut total_samples = 0usize;
    for (id, reply) in &replies {
        match reply {
            Ok(reply) => match client::eval_reply_of(reply) {
                Ok((_, result)) => {
                    weighted_loss += result.loss * result.num_samples as f64;
                    total_samples += result.num_samples;
                    // Eval-only participants (no train completion this
                    // round) still reveal their speed: synthesize a
                    // pacing observation from the eval timing so
                    // `Selector::PacingAware` can score them. Train
                    // completers already fed richer step-rate data via
                    // `complete_task` — don't dilute it with eval noise.
                    if arrived.binary_search(id).is_err() {
                        let meta = TaskMeta {
                            num_samples: result.num_samples,
                            completed_steps: result.num_samples,
                            train_wall_time_us: result.eval_time_us.max(1),
                            ..Default::default()
                        };
                        ctrl.pacing().observe_completion(id, &meta, Some(eval_round_time), round);
                    }
                }
                Err(e) => log_warn("scheduler", &format!("{id}: eval rejected: {e}")),
            },
            Err(e) => log_warn("scheduler", &format!("{id}: eval failed: {e:#}")),
        }
    }
    let community_eval_loss =
        (total_samples > 0).then(|| weighted_loss / total_samples as f64);

    let federation_round = round_sw.elapsed();
    ctrl.record(FedOp::FederationRound, federation_round);
    ctrl.set_round_ctx(SpanCtx::UNSET);
    Ok(RoundReport {
        round,
        participants: participants.len(),
        completed: arrived.len(),
        community_eval_loss,
        train_dispatch: dispatch_time,
        train_round: train_round_time,
        aggregation: aggregation_time,
        eval_dispatch,
        eval_round: eval_round_time,
        federation_round,
        completion_spread: outcome.completion_spread,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{FederationEnv, ModelSpec, TransportKind};
    use crate::net::Service;
    use crate::proto::{ErrorCode, EvalResult, PROTO_VERSION};
    use crate::tensor::TensorModel;
    use std::sync::Arc;

    /// Stub learner: acks train dispatch, but only `completes` ones
    /// call the completion callback. Everyone answers evaluation.
    struct EvalStub {
        id: String,
        callback: String,
        completes: bool,
        update: TensorModel,
    }

    impl Service for EvalStub {
        fn handle(&self, msg: Message) -> Message {
            match msg {
                Message::Hello { .. } => Message::HelloAck {
                    proto_version: PROTO_VERSION,
                    component: format!("learner/{}", self.id),
                    codecs: client::SUPPORTED_CODECS.to_vec(),
                },
                Message::RunTask { task_id, .. } => {
                    if self.completes {
                        let mut conn = crate::net::connect(&self.callback, None).unwrap();
                        client::hello_negotiate(conn.as_mut()).unwrap();
                        let proto =
                            ModelProto::from_model(&self.update, DType::F32, ByteOrder::Little);
                        let meta = TaskMeta {
                            num_samples: 10,
                            completed_steps: 8,
                            train_wall_time_us: 2_000,
                            ..TaskMeta::default()
                        };
                        client::mark_task_completed(conn.as_mut(), task_id, &self.id, proto, meta)
                            .unwrap();
                    }
                    Message::Ack { task_id, ok: true }
                }
                Message::EvaluateModel { task_id, .. } => Message::EvaluateModelReply {
                    task_id,
                    learner_id: self.id.clone(),
                    result: EvalResult { loss: 0.25, num_samples: 10, eval_time_us: 500 },
                },
                other => {
                    Message::error(ErrorCode::Unsupported, format!("unexpected {}", other.kind()))
                }
            }
        }
    }

    /// Eval-round timings feed the pacing registry: a learner that only
    /// ever evaluates (here: misses the train quorum but answers the
    /// eval broadcast) still ends up with a throughput profile for
    /// `Selector::Pacing` — while train completers keep their richer
    /// step-rate observation undiluted.
    #[test]
    fn eval_only_learner_feeds_pacing_registry() {
        let mut env = FederationEnv::builder("sync-eval-pacing")
            .learners(2)
            .rounds(1)
            .model(ModelSpec::mlp(4, 2, 8))
            .transport(TransportKind::InProc)
            .task_timeout_ms(10_000)
            .build();
        env.quorum_fraction = 0.5;
        let ctrl = Controller::new(env, None).unwrap();
        let _srv = crate::net::serve(
            "inproc://sync-eval-root",
            ctrl.clone() as Arc<dyn Service>,
            None,
        )
        .unwrap();
        let layout = ModelSpec::mlp(4, 2, 8).tensor_layout();
        ctrl.ship_model(TensorModel::random_init(&layout, &mut Rng::new(4)));
        let update = TensorModel::random_init(&layout, &mut Rng::new(5));
        let mut servers = Vec::new();
        for (id, completes) in [("worker", true), ("evaluator", false)] {
            let stub = Arc::new(EvalStub {
                id: id.to_string(),
                callback: "inproc://sync-eval-root".into(),
                completes,
                update: update.clone(),
            });
            let ep = format!("inproc://sync-eval-{id}");
            servers.push(crate::net::serve(&ep, stub as Arc<dyn Service>, None).unwrap());
            ctrl.register_learner(id, &ep, 10);
        }

        let report = run_sync_round(&ctrl, 1, &mut Rng::new(9)).unwrap();
        assert_eq!(report.completed, 1, "only the worker completes training");
        // The quorum-missing learner answered evaluation, so it now has
        // a throughput synthesized from eval telemetry (10 samples in
        // 500µs → 20k/s). A bare `observe_failure` entry would have no
        // throughput at all.
        let tp = ctrl.pacing().throughput("evaluator").expect("eval-only learner unprofiled");
        assert!((tp - 20_000.0).abs() < 1.0, "eval throughput off: {tp}");
        // The train completer's profile stays train-derived:
        // 8 steps / 2ms = 4000 steps/s, not overwritten by eval timing.
        let tp = ctrl.pacing().throughput("worker").expect("train completer unprofiled");
        assert!((tp - 4_000.0).abs() < 1.0, "train profile diluted by eval: {tp}");
    }
}
