//! Semi-synchronous rounds (Stripelis, Thompson & Ambite, 2022b).
//!
//! Instead of every learner completing a fixed number of epochs, each
//! learner trains for a *step budget* proportional to the hyperparameter
//! `λ` and then synchronizes. Fast and slow learners thus finish at
//! roughly the same wall-clock time, removing the straggler tail that
//! plain synchronous FedAvg pays every round.
//!
//! The protocol is **pacing-aware**: the fixed `λ × steps-per-epoch`
//! budget is only the fallback for learners the controller has never
//! measured. Once the pacing registry holds throughput profiles, each
//! learner `i` receives `budget_i = λ · t_target · throughput_i`
//! (t_target anchored so the slowest profiled learner keeps the fixed
//! budget — see [`crate::controller::pacing::PacingRegistry::step_budgets`]),
//! which is what actually equalizes round wall clock on a
//! heterogeneous fleet. The controller-side flow is otherwise identical
//! to the synchronous scheduler, so the round reuses
//! [`super::sync::run_round_with_budget`].

use super::super::Controller;
use crate::metrics::RoundReport;
use crate::util::Rng;
use anyhow::Result;

/// Steps per unit λ: one local epoch's worth of batches.
fn budget_for(ctrl: &Controller, lambda: f64) -> usize {
    let steps_per_epoch =
        ctrl.env.samples_per_learner.div_ceil(ctrl.env.batch_size).max(1);
    ((lambda * steps_per_epoch as f64).round() as usize).max(1)
}

pub fn run_semi_sync_round(
    ctrl: &Controller,
    round: u64,
    lambda: f64,
    rng: &mut Rng,
) -> Result<RoundReport> {
    let budget = budget_for(ctrl, lambda);
    super::sync::run_round_with_budget(ctrl, round, budget, true, rng)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{FederationEnv, ModelSpec};

    #[test]
    fn budget_scales_with_lambda_and_floors_at_one() {
        let env = FederationEnv::builder("t")
            .model(ModelSpec::mlp(4, 2, 8))
            .samples_per_learner(100)
            .batch_size(10)
            .build();
        let ctrl = crate::controller::Controller::new(env, None).unwrap();
        assert_eq!(budget_for(&ctrl, 1.0), 10);
        assert_eq!(budget_for(&ctrl, 2.5), 25);
        assert_eq!(budget_for(&ctrl, 0.001), 1);
    }
}
