//! Adaptive server optimizers (GlobalOpt row of Table 1).
//!
//! FedAdam / FedYogi / FedAdagrad (Reddi et al., *Adaptive Federated
//! Optimization*, 2021): treat `Δ = fedavg(models) − community` as a
//! pseudo-gradient and apply the corresponding adaptive update with
//! server-side moment state. The expensive part — the weighted mean —
//! reuses [`WeightedSum`], so all backends apply.

use super::fedavg::WeightedSum;
use super::{check_contributions, model_l2_norm, AggregationRule, Backend, Contribution};
use crate::tensor::TensorModel;
use crate::util::logging;
use anyhow::Result;
use std::sync::Arc;

const BETA1: f64 = 0.9;
const BETA2: f64 = 0.99;
const TAU: f64 = 1e-3; // adaptivity floor, per the paper's defaults

enum Variant {
    Adam,
    Yogi,
    Adagrad,
}

struct AdaptiveState {
    m: Vec<Vec<f32>>, // first moment per tensor
    v: Vec<Vec<f32>>, // second moment per tensor
}

/// Shared implementation of the three adaptive rules.
struct Adaptive {
    variant: Variant,
    server_lr: f64,
    state: Option<AdaptiveState>,
}

impl Adaptive {
    fn new(variant: Variant, server_lr: f64) -> Adaptive {
        Adaptive { variant, server_lr, state: None }
    }

    fn step(
        &mut self,
        current: &TensorModel,
        contributions: &[Contribution],
        backend: &Backend,
    ) -> Result<TensorModel> {
        check_contributions(current, contributions)?;
        let total: f64 = contributions.iter().map(|c| c.weight).sum();
        let models: Vec<Arc<TensorModel>> =
            contributions.iter().map(|c| Arc::clone(&c.model)).collect();
        let coeffs: Vec<f64> = contributions.iter().map(|c| c.weight / total).collect();
        let mean = WeightedSum::compute(&models, &coeffs, backend)?;
        // Norm bookkeeping (diagnostics only — never alters the update):
        // chunk-reduced ‖mean‖₂ tracks pseudo-gradient health per round.
        if logging::enabled(logging::LogLevel::Debug) {
            logging::log_debug(
                "server-opt",
                &format!("pseudo-gradient mean norm ‖m̄‖₂ = {:.6}", model_l2_norm(&mean, backend)),
            );
        }

        let state = self.state.get_or_insert_with(|| AdaptiveState {
            m: current.tensors.iter().map(|t| vec![0.0; t.elem_count()]).collect(),
            v: current.tensors.iter().map(|t| vec![0.0; t.elem_count()]).collect(),
        });

        let mut out = current.clone();
        for ti in 0..out.tensor_count() {
            let cur = &current.tensors[ti].data;
            let mean_t = &mean.tensors[ti].data;
            let m = &mut state.m[ti];
            let v = &mut state.v[ti];
            let dst = &mut out.tensors[ti].data;
            for ei in 0..dst.len() {
                let delta = (mean_t[ei] - cur[ei]) as f64;
                m[ei] = (BETA1 * m[ei] as f64 + (1.0 - BETA1) * delta) as f32;
                let d2 = delta * delta;
                let vv = v[ei] as f64;
                let nv = match self.variant {
                    Variant::Adam => BETA2 * vv + (1.0 - BETA2) * d2,
                    Variant::Yogi => vv - (1.0 - BETA2) * d2 * (vv - d2).signum(),
                    Variant::Adagrad => vv + d2,
                };
                v[ei] = nv as f32;
                dst[ei] =
                    (cur[ei] as f64 + self.server_lr * m[ei] as f64 / (nv.sqrt() + TAU)) as f32;
            }
        }
        // The mean was a chunked-backend temporary: hand its buffers back
        // so the next round's weighted sum allocates nothing.
        if let Some(scratch) = backend.scratch() {
            scratch.reclaim_model(Arc::new(mean));
        }
        Ok(out)
    }
}

macro_rules! adaptive_rule {
    ($name:ident, $variant:expr, $label:literal, $doc:literal) => {
        #[doc = $doc]
        pub struct $name(Adaptive);

        impl $name {
            pub fn new(server_lr: f64) -> $name {
                $name(Adaptive::new($variant, server_lr))
            }
        }

        impl AggregationRule for $name {
            fn aggregate(
                &mut self,
                current: &TensorModel,
                contributions: &[Contribution],
                backend: &Backend,
            ) -> Result<TensorModel> {
                self.0.step(current, contributions, backend)
            }

            fn name(&self) -> &'static str {
                $label
            }
        }
    };
}

adaptive_rule!(FedAdam, Variant::Adam, "fedadam", "FedAdam server optimizer.");
adaptive_rule!(FedYogi, Variant::Yogi, "fedyogi", "FedYogi server optimizer.");
adaptive_rule!(
    FedAdagrad,
    Variant::Adagrad,
    "fedadagrad",
    "FedAdagrad server optimizer."
);

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelSpec;
    use crate::util::Rng;

    fn setup() -> (TensorModel, Vec<Arc<TensorModel>>) {
        let layout = ModelSpec::mlp(4, 2, 8).tensor_layout();
        let mut rng = Rng::new(42);
        let current = TensorModel::random_init(&layout, &mut rng);
        let ms = (0..3)
            .map(|_| Arc::new(TensorModel::random_init(&layout, &mut rng)))
            .collect();
        (current, ms)
    }

    fn cs(ms: &[Arc<TensorModel>], weight: f64) -> Vec<Contribution> {
        ms.iter()
            .map(|m| Contribution { model: Arc::clone(m), weight })
            .collect()
    }

    fn run(rule: &mut dyn AggregationRule, rounds: usize) -> Vec<TensorModel> {
        let (mut current, ms) = setup();
        let mut outs = Vec::new();
        for _ in 0..rounds {
            current = rule.aggregate(&current, &cs(&ms, 100.0), &Backend::Sequential).unwrap();
            outs.push(current.clone());
        }
        outs
    }

    #[test]
    fn adaptive_rules_move_toward_the_mean() {
        let (current, ms) = setup();
        let mean = super::super::FedAvg::new()
            .aggregate(&current, &cs(&ms, 1.0), &Backend::Sequential)
            .unwrap();
        for rule in [
            &mut FedAdam::new(0.5) as &mut dyn AggregationRule,
            &mut FedYogi::new(0.5),
            &mut FedAdagrad::new(0.5),
        ] {
            let out = rule.aggregate(&current, &cs(&ms, 1.0), &Backend::Sequential).unwrap();
            // Distance to the fedavg mean must shrink vs. the start.
            let before = current.max_abs_diff(&mean);
            let after = out.max_abs_diff(&mean);
            assert!(after < before, "{}: {after} !< {before}", rule.name());
        }
    }

    #[test]
    fn moment_state_persists_across_rounds() {
        let mut rule = FedAdam::new(0.1);
        let outs = run(&mut rule, 3);
        // Repeated identical pseudo-gradients ⇒ momentum builds ⇒ the
        // step size (round-over-round movement) must change.
        let d1 = outs[0].max_abs_diff(&outs[1]);
        let d2 = outs[1].max_abs_diff(&outs[2]);
        assert!(d1 > 0.0 && d2 > 0.0);
        assert!((d1 - d2).abs() > 1e-9, "momentum had no effect");
    }

    #[test]
    fn backends_agree_for_adaptive_rules() {
        use crate::controller::aggregation::ScratchArena;
        use crate::util::ThreadPool;
        let (current, ms) = setup();
        let pool = Arc::new(ThreadPool::new(3));
        let backends = [
            Backend::Parallel(Arc::clone(&pool)),
            Backend::Chunked {
                pool: Arc::clone(&pool),
                scratch: Arc::new(ScratchArena::new()),
            },
        ];
        for backend in &backends {
            let mut a = FedAdam::new(0.3);
            let mut b = FedAdam::new(0.3);
            let seq = a.aggregate(&current, &cs(&ms, 2.0), &Backend::Sequential).unwrap();
            let other = b.aggregate(&current, &cs(&ms, 2.0), backend).unwrap();
            assert_eq!(seq, other, "{backend:?}");
        }
    }

    #[test]
    fn names_are_stable() {
        assert_eq!(FedAdam::new(0.1).name(), "fedadam");
        assert_eq!(FedYogi::new(0.1).name(), "fedyogi");
        assert_eq!(FedAdagrad::new(0.1).name(), "fedadagrad");
    }
}
