//! FedAvg and the shared weighted-sum engine.
//!
//! Figure 4: for `N` learners and `k` model tensors, the parallel backend
//! computes each aggregated tensor `T_i^C = Σ_j (w_j/W) · T_i^j` as one
//! independent task — "one thread per model tensor". The chunked backend
//! goes further: it partitions the *element space* `Σ_i |T_i|` into
//! ~`pool.size()` contiguous ranges, so parallelism is independent of
//! how the parameters happen to be sliced into tensors. Every element is
//! accumulated in learner order under all CPU backends, so the three
//! produce bitwise-identical results.

use super::{check_contributions, AggregationRule, Backend, Contribution, ScratchArena};
use crate::tensor::ops;
use crate::tensor::{FlatSpans, Tensor, TensorModel};
use crate::util::ThreadPool;
use anyhow::Result;
use std::sync::Arc;

/// The weighted-sum engine shared by every rule (and reused by the
/// baselines with different backends).
pub struct WeightedSum;

impl WeightedSum {
    /// `out_i = Σ_j coeff_j · model_j.tensor_i` for every tensor `i`.
    ///
    /// Models are passed as `Arc`s end to end — the engine never copies
    /// an input; its only O(params) writes go to the output (which the
    /// chunked backend draws from its [`ScratchArena`]).
    pub fn compute(
        models: &[Arc<TensorModel>],
        coeffs: &[f64],
        backend: &Backend,
    ) -> Result<TensorModel> {
        assert_eq!(models.len(), coeffs.len());
        assert!(!models.is_empty(), "weighted sum of zero models");
        match backend {
            Backend::Xla(f) => f(models, coeffs),
            Backend::Sequential => {
                let k = models[0].tensor_count();
                let tensors =
                    (0..k).map(|i| Self::one_tensor(models, coeffs, i)).collect::<Vec<_>>();
                Ok(TensorModel::new(tensors))
            }
            Backend::Parallel(pool) => {
                let k = models[0].tensor_count();
                let tensors = pool.parallel_map(k, |i| Self::one_tensor(models, coeffs, i));
                Ok(TensorModel::new(tensors))
            }
            Backend::Chunked { pool, scratch } => {
                Ok(Self::compute_chunked(models, coeffs, pool, scratch))
            }
        }
    }

    /// Aggregate tensor `i` across all models (a single Fig.-4 column).
    fn one_tensor(models: &[Arc<TensorModel>], coeffs: &[f64], i: usize) -> Tensor {
        let first = &models[0].tensors[i];
        let mut data = vec![0.0f32; first.elem_count()];
        ops::scaled_copy(&mut data, &first.data, coeffs[0] as f32);
        for (m, &c) in models.iter().zip(coeffs).skip(1) {
            ops::axpy(&mut data, &m.tensors[i].data, c as f32);
        }
        Tensor::new(first.name.clone(), first.shape.clone(), data)
    }

    /// Chunk-partitioned sweep: split the flat element space into
    /// ~`pool.size()` contiguous ranges; each worker walks its range's
    /// tensor spans and, per span, accumulates all learners before
    /// moving on (one pass over the output, per-learner inputs streamed
    /// through cache once per chunk). Output buffers come from `scratch`.
    fn compute_chunked(
        models: &[Arc<TensorModel>],
        coeffs: &[f64],
        pool: &ThreadPool,
        scratch: &ScratchArena,
    ) -> TensorModel {
        let reference = &models[0];
        // The per-tensor backends panic on mismatched layouts via the
        // kernels' length asserts; the span slicing below would silently
        // truncate instead, so enforce the same contract up front.
        for (j, m) in models.iter().enumerate().skip(1) {
            assert_eq!(
                m.tensor_count(),
                reference.tensor_count(),
                "model {j} tensor count mismatch"
            );
            for (a, b) in reference.tensors.iter().zip(&m.tensors) {
                assert_eq!(
                    a.data.len(),
                    b.data.len(),
                    "model {j} tensor '{}' length mismatch",
                    a.name
                );
            }
        }
        let offsets = reference.tensor_offsets();
        let total = *offsets.last().unwrap();
        let mut bufs: Vec<Vec<f32>> =
            reference.tensors.iter().map(|t| scratch.take(t.elem_count())).collect();
        {
            let outs: Vec<OutPtr> = bufs.iter_mut().map(|b| OutPtr(b.as_mut_ptr())).collect();
            let outs = &outs;
            pool.parallel_chunks(total, |range| {
                for (t, local) in FlatSpans::new(&offsets, range) {
                    // SAFETY: `parallel_chunks` hands out disjoint global
                    // ranges and `FlatSpans` maps them to disjoint
                    // (tensor, local) spans, so no two tasks alias any
                    // output element; each buffer outlives the scoped
                    // `parallel_chunks` barrier.
                    let dst = unsafe {
                        std::slice::from_raw_parts_mut(
                            outs[t].0.add(local.start),
                            local.len(),
                        )
                    };
                    ops::scaled_copy(dst, &models[0].tensors[t].data[local.clone()], coeffs[0] as f32);
                    for (m, &c) in models.iter().zip(coeffs).skip(1) {
                        ops::axpy(dst, &m.tensors[t].data[local.clone()], c as f32);
                    }
                }
            });
        }
        let tensors = reference
            .tensors
            .iter()
            .zip(bufs)
            .map(|(t, data)| Tensor::new(t.name.clone(), t.shape.clone(), data))
            .collect();
        TensorModel::new(tensors)
    }
}

/// Raw output cursor shared across pool workers; soundness argued at the
/// single write site in [`WeightedSum::compute_chunked`].
struct OutPtr(*mut f32);
unsafe impl Send for OutPtr {}
unsafe impl Sync for OutPtr {}

/// Plain federated averaging: community = Σ (w_j/W) · model_j.
#[derive(Default)]
pub struct FedAvg;

impl FedAvg {
    pub fn new() -> FedAvg {
        FedAvg
    }
}

impl AggregationRule for FedAvg {
    fn aggregate(
        &mut self,
        current: &TensorModel,
        contributions: &[Contribution],
        backend: &Backend,
    ) -> Result<TensorModel> {
        check_contributions(current, contributions)?;
        let total: f64 = contributions.iter().map(|c| c.weight).sum();
        let models: Vec<Arc<TensorModel>> =
            contributions.iter().map(|c| Arc::clone(&c.model)).collect();
        let coeffs: Vec<f64> = contributions.iter().map(|c| c.weight / total).collect();
        WeightedSum::compute(&models, &coeffs, backend)
    }

    fn name(&self) -> &'static str {
        "fedavg"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelSpec;
    use crate::util::prop::prop_check;
    use crate::util::{Rng, ThreadPool};
    use std::sync::Arc;

    fn setup(n: usize, seed: u64) -> (TensorModel, Vec<Arc<TensorModel>>) {
        let layout = ModelSpec::mlp(4, 5, 8).tensor_layout();
        let mut rng = Rng::new(seed);
        let current = TensorModel::random_init(&layout, &mut rng);
        let ms = (0..n)
            .map(|_| Arc::new(TensorModel::random_init(&layout, &mut rng)))
            .collect();
        (current, ms)
    }

    fn contributions(ms: &[Arc<TensorModel>], weights: &[f64]) -> Vec<Contribution> {
        ms.iter()
            .zip(weights)
            .map(|(m, &w)| Contribution { model: Arc::clone(m), weight: w })
            .collect()
    }

    fn chunked(threads: usize) -> Backend {
        Backend::Chunked {
            pool: Arc::new(ThreadPool::new(threads)),
            scratch: Arc::new(super::super::ScratchArena::new()),
        }
    }

    #[test]
    fn uniform_weights_give_arithmetic_mean() {
        let (current, ms) = setup(4, 1);
        let cs = contributions(&ms, &[1.0; 4]);
        let agg = FedAvg::new().aggregate(&current, &cs, &Backend::Sequential).unwrap();
        for (ti, t) in agg.tensors.iter().enumerate() {
            for (ei, v) in t.data.iter().enumerate() {
                let mean: f32 =
                    ms.iter().map(|m| m.tensors[ti].data[ei]).sum::<f32>() / 4.0;
                assert!((v - mean).abs() < 1e-5, "tensor {ti} elem {ei}");
            }
        }
    }

    #[test]
    fn weighted_mean_respects_sample_counts() {
        let (current, ms) = setup(2, 2);
        let cs = contributions(&ms, &[300.0, 100.0]);
        let agg = FedAvg::new().aggregate(&current, &cs, &Backend::Sequential).unwrap();
        let expect = 0.75 * ms[0].tensors[0].data[0] + 0.25 * ms[1].tensors[0].data[0];
        assert!((agg.tensors[0].data[0] - expect).abs() < 1e-6);
    }

    #[test]
    fn parallel_backend_matches_sequential_exactly() {
        let (current, ms) = setup(8, 3);
        let weights: Vec<f64> = (1..=8).map(|i| i as f64 * 10.0).collect();
        let cs = contributions(&ms, &weights);
        let seq = FedAvg::new().aggregate(&current, &cs, &Backend::Sequential).unwrap();
        let pool = Arc::new(ThreadPool::new(4));
        let cs = contributions(&ms, &weights);
        let par = FedAvg::new().aggregate(&current, &cs, &Backend::Parallel(pool)).unwrap();
        // Same operation order per tensor ⇒ bitwise identical.
        assert_eq!(seq, par);
    }

    #[test]
    fn chunked_backend_matches_sequential_exactly() {
        let (current, ms) = setup(6, 9);
        let weights: Vec<f64> = (1..=6).map(|i| i as f64 * 3.0).collect();
        let seq = FedAvg::new()
            .aggregate(&current, &contributions(&ms, &weights), &Backend::Sequential)
            .unwrap();
        for threads in [1, 2, 3, 7] {
            let backend = chunked(threads);
            let chk = FedAvg::new()
                .aggregate(&current, &contributions(&ms, &weights), &backend)
                .unwrap();
            // Same per-element accumulation order ⇒ bitwise identical.
            assert_eq!(seq, chk, "{threads} threads");
        }
    }

    #[test]
    fn chunked_backend_reuses_scratch_buffers() {
        let (current, ms) = setup(4, 10);
        let backend = chunked(3);
        let scratch = Arc::clone(backend.scratch().unwrap());
        let first = FedAvg::new()
            .aggregate(&current, &contributions(&ms, &[1.0; 4]), &backend)
            .unwrap();
        let after_first = scratch.fresh_allocations();
        assert_eq!(after_first, current.tensor_count());
        // Recycle the previous output (what the controller does when it
        // replaces the community model) — the next round allocates nothing.
        scratch.reclaim_model(Arc::new(first));
        let second = FedAvg::new()
            .aggregate(&current, &contributions(&ms, &[1.0; 4]), &backend)
            .unwrap();
        assert_eq!(scratch.fresh_allocations(), after_first);
        assert_eq!(second.tensor_count(), current.tensor_count());
    }

    #[test]
    fn single_contribution_is_identity() {
        let (current, ms) = setup(1, 4);
        let cs = contributions(&ms, &[123.0]);
        let agg = FedAvg::new().aggregate(&current, &cs, &Backend::Sequential).unwrap();
        assert!(agg.max_abs_diff(&ms[0]) < 1e-6);
    }

    #[test]
    fn aggregate_preserves_layout() {
        let (current, ms) = setup(3, 5);
        let cs = contributions(&ms, &[1.0, 2.0, 3.0]);
        let agg = FedAvg::new().aggregate(&current, &cs, &Backend::Sequential).unwrap();
        assert_eq!(agg.layout(), current.layout());
    }

    #[test]
    fn prop_fedavg_invariants() {
        prop_check("fedavg convexity & symmetry", 25, |g| {
            let n = g.usize_in(1..6);
            let seed = g.rng().next_u64();
            let (current, ms) = setup(n, seed);
            let weights: Vec<f64> = (0..n).map(|_| g.f64_in(0.1, 10.0)).collect();
            let cs = contributions(&ms, &weights);
            let agg = FedAvg::new().aggregate(&current, &cs, &Backend::Sequential).unwrap();
            // Convexity: every aggregated element lies within [min, max]
            // of the contributions (up to fp slack).
            for ti in 0..agg.tensor_count() {
                for ei in 0..agg.tensors[ti].data.len() {
                    let vals: Vec<f32> = ms.iter().map(|m| m.tensors[ti].data[ei]).collect();
                    let lo = vals.iter().cloned().fold(f32::INFINITY, f32::min);
                    let hi = vals.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                    let v = agg.tensors[ti].data[ei];
                    assert!(v >= lo - 1e-4 && v <= hi + 1e-4, "elem out of hull");
                }
            }
            // Permutation symmetry.
            let mut order: Vec<usize> = (0..n).collect();
            g.rng().shuffle(&mut order);
            let ms2: Vec<Arc<TensorModel>> = order.iter().map(|&i| Arc::clone(&ms[i])).collect();
            let w2: Vec<f64> = order.iter().map(|&i| weights[i]).collect();
            let cs2 = contributions(&ms2, &w2);
            let agg2 = FedAvg::new().aggregate(&current, &cs2, &Backend::Sequential).unwrap();
            assert!(agg.max_abs_diff(&agg2) < 1e-4, "not permutation symmetric");
        });
    }
}
