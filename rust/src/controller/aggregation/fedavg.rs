//! FedAvg and the shared per-tensor weighted-sum engine.
//!
//! Figure 4: for `N` learners and `k` model tensors, the parallel backend
//! computes each aggregated tensor `T_i^C = Σ_j (w_j/W) · T_i^j` as one
//! independent task — "one thread per model tensor".

use super::{check_contributions, AggregationRule, Backend, Contribution};
use crate::tensor::ops;
use crate::tensor::{Tensor, TensorModel};
use anyhow::Result;

/// The weighted-sum engine shared by every rule (and reused by the
/// baselines with different backends).
pub struct WeightedSum;

impl WeightedSum {
    /// `out_i = Σ_j coeff_j · model_j.tensor_i` for every tensor `i`.
    pub fn compute(
        models: &[&TensorModel],
        coeffs: &[f64],
        backend: &Backend,
    ) -> Result<TensorModel> {
        assert_eq!(models.len(), coeffs.len());
        match backend {
            Backend::Xla(f) => f(models, coeffs),
            Backend::Sequential => {
                let k = models[0].tensor_count();
                let tensors =
                    (0..k).map(|i| Self::one_tensor(models, coeffs, i)).collect::<Vec<_>>();
                Ok(TensorModel::new(tensors))
            }
            Backend::Parallel(pool) => {
                let k = models[0].tensor_count();
                let tensors = pool.parallel_map(k, |i| Self::one_tensor(models, coeffs, i));
                Ok(TensorModel::new(tensors))
            }
        }
    }

    /// Aggregate tensor `i` across all models (a single Fig.-4 column).
    fn one_tensor(models: &[&TensorModel], coeffs: &[f64], i: usize) -> Tensor {
        let first = &models[0].tensors[i];
        let mut data = vec![0.0f32; first.elem_count()];
        ops::scaled_copy(&mut data, &first.data, coeffs[0] as f32);
        for (m, &c) in models.iter().zip(coeffs).skip(1) {
            ops::axpy(&mut data, &m.tensors[i].data, c as f32);
        }
        Tensor::new(first.name.clone(), first.shape.clone(), data)
    }
}

/// Plain federated averaging: community = Σ (w_j/W) · model_j.
#[derive(Default)]
pub struct FedAvg;

impl FedAvg {
    pub fn new() -> FedAvg {
        FedAvg
    }
}

impl AggregationRule for FedAvg {
    fn aggregate(
        &mut self,
        current: &TensorModel,
        contributions: &[Contribution<'_>],
        backend: &Backend,
    ) -> Result<TensorModel> {
        check_contributions(current, contributions)?;
        let total: f64 = contributions.iter().map(|c| c.weight).sum();
        let models: Vec<&TensorModel> = contributions.iter().map(|c| c.model).collect();
        let coeffs: Vec<f64> = contributions.iter().map(|c| c.weight / total).collect();
        WeightedSum::compute(&models, &coeffs, backend)
    }

    fn name(&self) -> &'static str {
        "fedavg"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelSpec;
    use crate::util::prop::prop_check;
    use crate::util::{Rng, ThreadPool};
    use std::sync::Arc;

    fn setup(n: usize, seed: u64) -> (TensorModel, Vec<TensorModel>) {
        let layout = ModelSpec::mlp(4, 5, 8).tensor_layout();
        let mut rng = Rng::new(seed);
        let current = TensorModel::random_init(&layout, &mut rng);
        let ms = (0..n).map(|_| TensorModel::random_init(&layout, &mut rng)).collect();
        (current, ms)
    }

    fn contributions<'a>(ms: &'a [TensorModel], weights: &[f64]) -> Vec<Contribution<'a>> {
        ms.iter()
            .zip(weights)
            .map(|(m, &w)| Contribution { model: m, weight: w })
            .collect()
    }

    #[test]
    fn uniform_weights_give_arithmetic_mean() {
        let (current, ms) = setup(4, 1);
        let cs = contributions(&ms, &[1.0; 4]);
        let agg = FedAvg::new().aggregate(&current, &cs, &Backend::Sequential).unwrap();
        for (ti, t) in agg.tensors.iter().enumerate() {
            for (ei, v) in t.data.iter().enumerate() {
                let mean: f32 =
                    ms.iter().map(|m| m.tensors[ti].data[ei]).sum::<f32>() / 4.0;
                assert!((v - mean).abs() < 1e-5, "tensor {ti} elem {ei}");
            }
        }
    }

    #[test]
    fn weighted_mean_respects_sample_counts() {
        let (current, ms) = setup(2, 2);
        let cs = contributions(&ms, &[300.0, 100.0]);
        let agg = FedAvg::new().aggregate(&current, &cs, &Backend::Sequential).unwrap();
        let expect = 0.75 * ms[0].tensors[0].data[0] + 0.25 * ms[1].tensors[0].data[0];
        assert!((agg.tensors[0].data[0] - expect).abs() < 1e-6);
    }

    #[test]
    fn parallel_backend_matches_sequential_exactly() {
        let (current, ms) = setup(8, 3);
        let weights: Vec<f64> = (1..=8).map(|i| i as f64 * 10.0).collect();
        let cs = contributions(&ms, &weights);
        let seq = FedAvg::new().aggregate(&current, &cs, &Backend::Sequential).unwrap();
        let pool = Arc::new(ThreadPool::new(4));
        let cs = contributions(&ms, &weights);
        let par = FedAvg::new().aggregate(&current, &cs, &Backend::Parallel(pool)).unwrap();
        // Same operation order per tensor ⇒ bitwise identical.
        assert_eq!(seq, par);
    }

    #[test]
    fn single_contribution_is_identity() {
        let (current, ms) = setup(1, 4);
        let cs = contributions(&ms, &[123.0]);
        let agg = FedAvg::new().aggregate(&current, &cs, &Backend::Sequential).unwrap();
        assert!(agg.max_abs_diff(&ms[0]) < 1e-6);
    }

    #[test]
    fn aggregate_preserves_layout() {
        let (current, ms) = setup(3, 5);
        let cs = contributions(&ms, &[1.0, 2.0, 3.0]);
        let agg = FedAvg::new().aggregate(&current, &cs, &Backend::Sequential).unwrap();
        assert_eq!(agg.layout(), current.layout());
    }

    #[test]
    fn prop_fedavg_invariants() {
        prop_check("fedavg convexity & symmetry", 25, |g| {
            let n = g.usize_in(1..6);
            let seed = g.rng().next_u64();
            let (current, ms) = setup(n, seed);
            let weights: Vec<f64> = (0..n).map(|_| g.f64_in(0.1, 10.0)).collect();
            let cs = contributions(&ms, &weights);
            let agg = FedAvg::new().aggregate(&current, &cs, &Backend::Sequential).unwrap();
            // Convexity: every aggregated element lies within [min, max]
            // of the contributions (up to fp slack).
            for ti in 0..agg.tensor_count() {
                for ei in 0..agg.tensors[ti].data.len() {
                    let vals: Vec<f32> = ms.iter().map(|m| m.tensors[ti].data[ei]).collect();
                    let lo = vals.iter().cloned().fold(f32::INFINITY, f32::min);
                    let hi = vals.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                    let v = agg.tensors[ti].data[ei];
                    assert!(v >= lo - 1e-4 && v <= hi + 1e-4, "elem out of hull");
                }
            }
            // Permutation symmetry.
            let mut order: Vec<usize> = (0..n).collect();
            g.rng().shuffle(&mut order);
            let ms2: Vec<TensorModel> = order.iter().map(|&i| ms[i].clone()).collect();
            let w2: Vec<f64> = order.iter().map(|&i| weights[i]).collect();
            let cs2 = contributions(&ms2, &w2);
            let agg2 = FedAvg::new().aggregate(&current, &cs2, &Backend::Sequential).unwrap();
            assert!(agg.max_abs_diff(&agg2) < 1e-4, "not permutation symmetric");
        });
    }
}
