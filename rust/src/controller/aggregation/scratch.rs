//! Reusable output-buffer arena for the aggregation hot path.
//!
//! The chunked weighted-sum backend writes each aggregated tensor into a
//! buffer checked out of a [`ScratchArena`] instead of a fresh `Vec`.
//! When the controller replaces the community model, the previous
//! round's buffers are reclaimed (see [`ScratchArena::reclaim_model`]),
//! so once the federation reaches steady state — same model layout every
//! round — `WeightedSum::compute` performs **zero heap allocation** for
//! its outputs: round `N` aggregates into the buffers round `N-1`'s
//! community model vacated.
//!
//! Buffers in the free list keep their previous contents (`len` stays at
//! the initialized extent), so checkout can shrink/grow them with safe
//! `Vec::resize`: no `unsafe`, and the zero-fill only happens for bytes
//! a buffer never held before.

use crate::tensor::TensorModel;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// Free-list caps. Count bounds bookkeeping; the element cap bounds
/// actual memory: rules whose *output* is not arena-drawn (the adaptive
/// optimizers deep-clone `current`) recycle one model's worth of
/// community buffers per round without a matching checkout, so without
/// a byte bound the pool would grow by a full model every round. 2^26
/// f32s = 256 MiB retained worst case; steady-state FedAvg needs only
/// one model's worth.
const MAX_POOLED: usize = 4096;
const MAX_POOLED_ELEMS: usize = 1 << 26;

/// A pool of reusable `Vec<f32>` element buffers.
pub struct ScratchArena {
    /// Free buffers plus the running sum of their capacities.
    free: Mutex<(Vec<Vec<f32>>, usize)>,
    fresh_allocs: AtomicUsize,
    max_pooled: usize,
    max_pooled_elems: usize,
}

impl Default for ScratchArena {
    fn default() -> ScratchArena {
        ScratchArena::new()
    }
}

impl ScratchArena {
    pub fn new() -> ScratchArena {
        ScratchArena::with_caps(MAX_POOLED, MAX_POOLED_ELEMS)
    }

    /// Arena with explicit free-list caps (tests; memory-tight deploys).
    pub fn with_caps(max_pooled: usize, max_pooled_elems: usize) -> ScratchArena {
        ScratchArena {
            free: Mutex::new((Vec::new(), 0)),
            fresh_allocs: AtomicUsize::new(0),
            max_pooled,
            max_pooled_elems,
        }
    }

    /// Check out a buffer of exactly `len` elements. Reuses the smallest
    /// pooled buffer whose capacity fits (no reallocation); falls back to
    /// a fresh zeroed allocation, which is counted in
    /// [`ScratchArena::fresh_allocations`].
    pub fn take(&self, len: usize) -> Vec<f32> {
        let mut guard = self.free.lock().unwrap();
        let (free, pooled_elems) = &mut *guard;
        let mut best: Option<(usize, usize)> = None; // (index, capacity)
        for (i, buf) in free.iter().enumerate() {
            let cap = buf.capacity();
            let tighter = match best {
                None => true,
                Some((_, c)) => cap < c,
            };
            if cap >= len && tighter {
                best = Some((i, cap));
                if cap == len {
                    break;
                }
            }
        }
        if let Some((i, cap)) = best {
            let mut buf = free.swap_remove(i);
            *pooled_elems -= cap;
            drop(guard);
            buf.resize(len, 0.0);
            return buf;
        }
        drop(guard);
        self.fresh_allocs.fetch_add(1, Ordering::Relaxed);
        vec![0.0; len]
    }

    /// Return a buffer to the free list. Buffers beyond the count or
    /// memory caps are dropped instead of pooled.
    pub fn recycle(&self, buf: Vec<f32>) {
        if buf.capacity() == 0 {
            return;
        }
        let mut guard = self.free.lock().unwrap();
        let (free, pooled_elems) = &mut *guard;
        if free.len() < self.max_pooled && *pooled_elems + buf.capacity() <= self.max_pooled_elems
        {
            *pooled_elems += buf.capacity();
            free.push(buf);
        }
    }

    /// Reclaim every tensor buffer of a model nobody else references.
    /// Returns `false` (and reclaims nothing) if the `Arc` is still
    /// shared — e.g. a scheduler snapshot is alive — which simply means
    /// the next round pays its allocations; correctness is unaffected.
    pub fn reclaim_model(&self, model: Arc<TensorModel>) -> bool {
        match Arc::try_unwrap(model) {
            Ok(model) => {
                for t in model.tensors {
                    self.recycle(t.data);
                }
                true
            }
            Err(_) => false,
        }
    }

    /// Buffers currently pooled.
    pub fn pooled(&self) -> usize {
        self.free.lock().unwrap().0.len()
    }

    /// Total f32 elements of capacity currently pooled.
    pub fn pooled_elems(&self) -> usize {
        self.free.lock().unwrap().1
    }

    /// Total fresh heap allocations served so far (steady-state rounds
    /// must not move this counter — asserted by the controller tests).
    pub fn fresh_allocations(&self) -> usize {
        self.fresh_allocs.load(Ordering::Relaxed)
    }
}

/// The arena doubles as the data plane's decode-buffer source: inbound
/// model streams fill buffers the previous community model (and the
/// store's evicted contributions) vacated, so a steady-state streamed
/// round allocates nothing on ingest either.
impl crate::proto::ingest::BufferPool for ScratchArena {
    fn take(&self, len: usize) -> Vec<f32> {
        ScratchArena::take(self, len)
    }

    fn recycle(&self, buf: Vec<f32>) {
        ScratchArena::recycle(self, buf)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    #[test]
    fn take_recycle_roundtrip_reuses_capacity() {
        let arena = ScratchArena::new();
        let buf = arena.take(100);
        assert_eq!(buf.len(), 100);
        assert_eq!(arena.fresh_allocations(), 1);
        let ptr = buf.as_ptr();
        arena.recycle(buf);
        assert_eq!(arena.pooled(), 1);
        // Same-size checkout reuses the same allocation.
        let buf = arena.take(100);
        assert_eq!(buf.as_ptr(), ptr);
        assert_eq!(arena.fresh_allocations(), 1);
        // Smaller checkout also reuses (shrink, no realloc).
        arena.recycle(buf);
        let buf = arena.take(40);
        assert_eq!(buf.len(), 40);
        assert_eq!(buf.as_ptr(), ptr);
        assert_eq!(arena.fresh_allocations(), 1);
    }

    #[test]
    fn take_prefers_tightest_fit() {
        let arena = ScratchArena::new();
        let small = arena.take(10);
        let large = arena.take(1000);
        let large_ptr = large.as_ptr();
        arena.recycle(small);
        arena.recycle(large);
        // A 500-element request must not burn the 10-cap buffer, and must
        // pick the 1000-cap one over allocating.
        let buf = arena.take(500);
        assert_eq!(buf.as_ptr(), large_ptr);
        assert_eq!(arena.fresh_allocations(), 2);
        assert_eq!(arena.pooled(), 1);
    }

    #[test]
    fn reclaim_model_requires_unique_ownership() {
        let arena = ScratchArena::new();
        let model = Arc::new(TensorModel::new(vec![
            Tensor::new("a", vec![3], vec![1.0, 2.0, 3.0]),
            Tensor::new("b", vec![2], vec![4.0, 5.0]),
        ]));
        let held = Arc::clone(&model);
        assert!(!arena.reclaim_model(model));
        assert_eq!(arena.pooled(), 0);
        assert!(arena.reclaim_model(held));
        assert_eq!(arena.pooled(), 2);
    }

    #[test]
    fn zero_len_buffers_are_not_pooled() {
        let arena = ScratchArena::new();
        arena.recycle(Vec::new());
        assert_eq!(arena.pooled(), 0);
        let buf = arena.take(0);
        assert!(buf.is_empty());
    }

    #[test]
    fn memory_cap_bounds_retained_buffers() {
        // Recycling more capacity than the element cap drops the excess
        // instead of retaining it forever (the adaptive-rule + chunked
        // backend round pattern recycles without matching checkouts).
        let arena = ScratchArena::with_caps(100, 1000);
        for _ in 0..6 {
            arena.recycle(Vec::with_capacity(250));
        }
        assert_eq!(arena.pooled(), 4);
        assert_eq!(arena.pooled_elems(), 1000);
        // Elements are re-accounted on checkout.
        let buf = arena.take(250);
        assert_eq!(arena.pooled(), 3);
        assert_eq!(arena.pooled_elems(), 750);
        drop(buf);
        // Count cap applies independently of the element cap.
        let tiny = ScratchArena::with_caps(2, 1000);
        for _ in 0..5 {
            tiny.recycle(Vec::with_capacity(8));
        }
        assert_eq!(tiny.pooled(), 2);
        assert_eq!(tiny.pooled_elems(), 16);
    }
}
