//! Model aggregation — the paper's headline contribution (§3, Fig. 4).
//!
//! An [`AggregationRule`] combines `N` learner models (with weights,
//! typically sample counts) into the new community model. The rule is
//! orthogonal to the *backend* that executes the weighted sums:
//!
//! * [`Backend::Sequential`] — one thread, tensor after tensor (the
//!   paper's "MetisFL gRPC" configuration),
//! * [`Backend::Parallel`]  — one pool task per model tensor, the
//!   "embarrassingly parallelized" OpenMP analog ("MetisFL gRPC+OpenMP"),
//! * [`Backend::Xla`]       — offload to the AOT-compiled Pallas fedavg
//!   kernel via PJRT (ablation, wired in `runtime`).
//!
//! Rules provided: [`FedAvg`] and the adaptive server optimizers
//! [`FedAdam`], [`FedYogi`], [`FedAdagrad`] (Reddi et al. 2021), which
//! all consume the FedAvg mean as a pseudo-gradient — so they reuse the
//! same parallel weighted-sum hot path.

pub mod fedavg;
pub mod server_opt;

pub use fedavg::{FedAvg, WeightedSum};
pub use server_opt::{FedAdagrad, FedAdam, FedYogi};

use crate::config::{AggregationBackend, AggregationSpec};
use crate::tensor::TensorModel;
use crate::util::ThreadPool;
use anyhow::{bail, Result};
use std::sync::Arc;

/// One learner's contribution to a round.
pub struct Contribution<'a> {
    pub model: &'a TensorModel,
    /// Aggregation weight (the paper uses training-sample counts).
    pub weight: f64,
}

/// Execution backend for the per-tensor weighted sums.
#[derive(Clone)]
pub enum Backend {
    Sequential,
    Parallel(Arc<ThreadPool>),
    /// XLA offload; boxed function so `controller` need not depend on the
    /// runtime module directly (wired by `runtime::xla_backend`).
    Xla(Arc<dyn Fn(&[&TensorModel], &[f64]) -> Result<TensorModel> + Send + Sync>),
}

impl std::fmt::Debug for Backend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Backend::Sequential => write!(f, "Sequential"),
            Backend::Parallel(p) => write!(f, "Parallel({} threads)", p.size()),
            Backend::Xla(_) => write!(f, "Xla"),
        }
    }
}

impl Backend {
    /// Build from config (Xla must be wired explicitly via the runtime).
    pub fn from_spec(spec: &AggregationSpec) -> Backend {
        match spec.backend {
            AggregationBackend::Sequential => Backend::Sequential,
            AggregationBackend::Parallel => {
                let threads = if spec.threads == 0 {
                    crate::util::threadpool::hardware_threads()
                } else {
                    spec.threads
                };
                Backend::Parallel(Arc::new(ThreadPool::new(threads)))
            }
            AggregationBackend::Xla => {
                // Falls back to Sequential until the runtime injects the
                // compiled kernel (Controller::set_xla_backend).
                Backend::Sequential
            }
        }
    }
}

/// A global aggregation rule.
pub trait AggregationRule: Send + Sync {
    /// Combine contributions into the next community model.
    ///
    /// `current` is the present community model (used by adaptive rules;
    /// plain FedAvg ignores it).
    fn aggregate(
        &mut self,
        current: &TensorModel,
        contributions: &[Contribution<'_>],
        backend: &Backend,
    ) -> Result<TensorModel>;

    fn name(&self) -> &'static str;
}

/// Build a rule by config name.
pub fn rule_from_spec(spec: &AggregationSpec) -> Result<Box<dyn AggregationRule>> {
    Ok(match spec.rule.as_str() {
        "fedavg" => Box::new(FedAvg::new()),
        "fedadam" => Box::new(FedAdam::new(spec.server_lr)),
        "fedyogi" => Box::new(FedYogi::new(spec.server_lr)),
        "fedadagrad" => Box::new(FedAdagrad::new(spec.server_lr)),
        other => bail!("unknown aggregation rule '{other}'"),
    })
}

/// Validate contributions: non-empty, matching layouts, positive weights.
pub(crate) fn check_contributions(
    current: &TensorModel,
    contributions: &[Contribution<'_>],
) -> Result<()> {
    if contributions.is_empty() {
        bail!("aggregate() with zero contributions");
    }
    let total: f64 = contributions.iter().map(|c| c.weight).sum();
    if total <= 0.0 {
        bail!("aggregate() with non-positive total weight {total}");
    }
    for (i, c) in contributions.iter().enumerate() {
        if c.weight < 0.0 {
            bail!("contribution {i} has negative weight {}", c.weight);
        }
        if c.model.tensor_count() != current.tensor_count() {
            bail!(
                "contribution {i} tensor count {} != community {}",
                c.model.tensor_count(),
                current.tensor_count()
            );
        }
        for (a, b) in c.model.tensors.iter().zip(&current.tensors) {
            if a.shape != b.shape {
                bail!("contribution {i} tensor '{}' shape {:?} != {:?}", a.name, a.shape, b.shape);
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelSpec;
    use crate::util::Rng;

    fn models(n: usize) -> (TensorModel, Vec<TensorModel>) {
        let layout = ModelSpec::mlp(4, 3, 8).tensor_layout();
        let mut rng = Rng::new(77);
        let current = TensorModel::random_init(&layout, &mut rng);
        let ms = (0..n).map(|_| TensorModel::random_init(&layout, &mut rng)).collect();
        (current, ms)
    }

    #[test]
    fn rule_factory_known_and_unknown() {
        for rule in ["fedavg", "fedadam", "fedyogi", "fedadagrad"] {
            let spec = AggregationSpec { rule: rule.into(), ..Default::default() };
            assert!(rule_from_spec(&spec).is_ok(), "{rule}");
        }
        let spec = AggregationSpec { rule: "bogus".into(), ..Default::default() };
        assert!(rule_from_spec(&spec).is_err());
    }

    #[test]
    fn contribution_validation() {
        let (current, ms) = models(2);
        let ok = vec![
            Contribution { model: &ms[0], weight: 1.0 },
            Contribution { model: &ms[1], weight: 2.0 },
        ];
        assert!(check_contributions(&current, &ok).is_ok());
        assert!(check_contributions(&current, &[]).is_err());
        let zero = vec![Contribution { model: &ms[0], weight: 0.0 }];
        assert!(check_contributions(&current, &zero).is_err());
        let neg = vec![
            Contribution { model: &ms[0], weight: 2.0 },
            Contribution { model: &ms[1], weight: -1.0 },
        ];
        assert!(check_contributions(&current, &neg).is_err());
        // Mismatched layout.
        let other = TensorModel::zeros(&ModelSpec::mlp(4, 2, 8).tensor_layout());
        let bad = vec![Contribution { model: &other, weight: 1.0 }];
        assert!(check_contributions(&current, &bad).is_err());
    }

    #[test]
    fn backend_from_spec() {
        let spec = AggregationSpec {
            backend: crate::config::AggregationBackend::Parallel,
            threads: 3,
            ..Default::default()
        };
        match Backend::from_spec(&spec) {
            Backend::Parallel(p) => assert_eq!(p.size(), 3),
            other => panic!("expected parallel, got {other:?}"),
        }
        let spec = AggregationSpec {
            backend: crate::config::AggregationBackend::Sequential,
            ..Default::default()
        };
        assert!(matches!(Backend::from_spec(&spec), Backend::Sequential));
    }
}
