//! Model aggregation — the paper's headline contribution (§3, Fig. 4).
//!
//! An [`AggregationRule`] combines `N` learner models (with weights,
//! typically sample counts) into the new community model. The rule is
//! orthogonal to the *backend* that executes the weighted sums:
//!
//! * [`Backend::Sequential`] — one thread, tensor after tensor (the
//!   paper's "MetisFL gRPC" configuration),
//! * [`Backend::Parallel`]  — one pool task per model tensor, the
//!   "embarrassingly parallelized" OpenMP analog ("MetisFL gRPC+OpenMP",
//!   Fig. 4). Parallelism is capped by the tensor count and skewed by
//!   tensor sizes: a 2-tensor model uses 2 threads no matter the
//!   machine, and one giant tensor serializes the whole sum.
//! * [`Backend::Chunked`]   — flatten the model's element space across
//!   all tensors and split it into ~`pool.size()` contiguous ranges;
//!   each worker sweeps its range across all learner models in learner
//!   order. Work is balanced by *elements*, not tensors, so utilization
//!   is full regardless of layout, and each output element is produced
//!   in the same accumulation order as `Sequential` — results are
//!   **bitwise identical** across the three CPU backends. Outputs are
//!   written into a [`ScratchArena`] so steady-state rounds allocate
//!   nothing (see [`scratch`]).
//! * [`Backend::Xla`]       — offload to the AOT-compiled Pallas fedavg
//!   kernel via PJRT (ablation, wired in `runtime`).
//!
//! ## Zero-copy model plumbing
//!
//! [`Contribution`] (and the store's `StoredModel`, and the controller's
//! community slot) hold `Arc<TensorModel>`: inserting, selecting,
//! shipping and aggregating pass reference-counted pointers, never deep
//! copies. The only O(params) memory traffic per round is the weighted
//! sum itself plus wire (de)serialization.
//!
//! Rules provided: [`FedAvg`] and the adaptive server optimizers
//! [`FedAdam`], [`FedYogi`], [`FedAdagrad`] (Reddi et al. 2021), which
//! all consume the FedAvg mean as a pseudo-gradient — so they reuse the
//! same parallel weighted-sum hot path.

pub mod fedavg;
pub mod scratch;
pub mod server_opt;

pub use fedavg::{FedAvg, WeightedSum};
pub use scratch::ScratchArena;
pub use server_opt::{FedAdagrad, FedAdam, FedYogi};

use crate::config::{AggregationBackend, AggregationSpec};
use crate::tensor::{ops, FlatSpans, TensorModel};
use crate::util::ThreadPool;
use anyhow::{bail, Result};
use std::sync::Arc;

/// One learner's contribution to a round. Holds the model by `Arc`, so
/// building a round's contribution set from the store shares pointers
/// instead of deep-copying megabytes of parameters.
pub struct Contribution {
    pub model: Arc<TensorModel>,
    /// Aggregation weight (the paper uses training-sample counts).
    pub weight: f64,
}

/// Signature of an injected XLA aggregation kernel.
pub type XlaAggFn = Arc<dyn Fn(&[Arc<TensorModel>], &[f64]) -> Result<TensorModel> + Send + Sync>;

/// Execution backend for the weighted sums.
#[derive(Clone)]
pub enum Backend {
    Sequential,
    Parallel(Arc<ThreadPool>),
    /// Chunk-partitioned element sweep with reusable output buffers.
    Chunked { pool: Arc<ThreadPool>, scratch: Arc<ScratchArena> },
    /// XLA offload; boxed function so `controller` need not depend on the
    /// runtime module directly (wired by `runtime::xla_fedavg_backend`).
    Xla(XlaAggFn),
}

impl std::fmt::Debug for Backend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Backend::Sequential => write!(f, "Sequential"),
            Backend::Parallel(p) => write!(f, "Parallel({} threads)", p.size()),
            Backend::Chunked { pool, scratch } => write!(
                f,
                "Chunked({} threads, {} pooled buffers)",
                pool.size(),
                scratch.pooled()
            ),
            Backend::Xla(_) => write!(f, "Xla"),
        }
    }
}

impl Backend {
    /// Build from config (Xla must be wired explicitly via the runtime).
    pub fn from_spec(spec: &AggregationSpec) -> Backend {
        let threads = |spec: &AggregationSpec| {
            if spec.threads == 0 {
                crate::util::threadpool::hardware_threads()
            } else {
                spec.threads
            }
        };
        match spec.backend {
            AggregationBackend::Sequential => Backend::Sequential,
            AggregationBackend::Parallel => {
                Backend::Parallel(Arc::new(ThreadPool::new(threads(spec))))
            }
            AggregationBackend::Chunked => Backend::Chunked {
                pool: Arc::new(ThreadPool::new(threads(spec))),
                scratch: Arc::new(ScratchArena::new()),
            },
            AggregationBackend::Xla => {
                // Falls back to Sequential until the runtime injects the
                // compiled kernel (Controller::set_xla_backend).
                Backend::Sequential
            }
        }
    }

    /// The scratch arena, when this backend owns one.
    pub fn scratch(&self) -> Option<&Arc<ScratchArena>> {
        match self {
            Backend::Chunked { scratch, .. } => Some(scratch),
            _ => None,
        }
    }
}

/// `‖model‖₂` with an f64 accumulator, computed with chunk-local partial
/// sums ([`ops::dot`] per span, reduced in chunk order via
/// [`ThreadPool::reduce_chunks`]) when the backend owns a pool, serially
/// otherwise. Deterministic for a fixed backend configuration. Used for
/// round norm bookkeeping by the controller and the server optimizers.
pub fn model_l2_norm(model: &TensorModel, backend: &Backend) -> f64 {
    let pool = match backend {
        Backend::Parallel(pool) | Backend::Chunked { pool, .. } => Some(pool),
        Backend::Sequential | Backend::Xla(_) => None,
    };
    let sq = match pool {
        Some(pool) => {
            let offsets = model.tensor_offsets();
            pool.reduce_chunks(model.param_count(), |range| {
                FlatSpans::new(&offsets, range)
                    .map(|(t, local)| {
                        let s = &model.tensors[t].data[local];
                        ops::dot(s, s)
                    })
                    .sum()
            })
        }
        None => model.tensors.iter().map(|t| ops::dot(&t.data, &t.data)).sum(),
    };
    sq.sqrt()
}

/// A global aggregation rule.
pub trait AggregationRule: Send + Sync {
    /// Combine contributions into the next community model.
    ///
    /// `current` is the present community model (used by adaptive rules;
    /// plain FedAvg ignores it).
    fn aggregate(
        &mut self,
        current: &TensorModel,
        contributions: &[Contribution],
        backend: &Backend,
    ) -> Result<TensorModel>;

    fn name(&self) -> &'static str;
}

/// Build a rule by config name.
pub fn rule_from_spec(spec: &AggregationSpec) -> Result<Box<dyn AggregationRule>> {
    Ok(match spec.rule.as_str() {
        "fedavg" => Box::new(FedAvg::new()),
        "fedadam" => Box::new(FedAdam::new(spec.server_lr)),
        "fedyogi" => Box::new(FedYogi::new(spec.server_lr)),
        "fedadagrad" => Box::new(FedAdagrad::new(spec.server_lr)),
        other => bail!("unknown aggregation rule '{other}'"),
    })
}

/// Validate contributions: non-empty, matching layouts, positive weights.
pub(crate) fn check_contributions(
    current: &TensorModel,
    contributions: &[Contribution],
) -> Result<()> {
    if contributions.is_empty() {
        bail!("aggregate() with zero contributions");
    }
    let total: f64 = contributions.iter().map(|c| c.weight).sum();
    if total <= 0.0 {
        bail!("aggregate() with non-positive total weight {total}");
    }
    for (i, c) in contributions.iter().enumerate() {
        if c.weight < 0.0 {
            bail!("contribution {i} has negative weight {}", c.weight);
        }
        if c.model.tensor_count() != current.tensor_count() {
            bail!(
                "contribution {i} tensor count {} != community {}",
                c.model.tensor_count(),
                current.tensor_count()
            );
        }
        for (a, b) in c.model.tensors.iter().zip(&current.tensors) {
            if a.shape != b.shape {
                bail!("contribution {i} tensor '{}' shape {:?} != {:?}", a.name, a.shape, b.shape);
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelSpec;
    use crate::util::Rng;

    fn models(n: usize) -> (TensorModel, Vec<Arc<TensorModel>>) {
        let layout = ModelSpec::mlp(4, 3, 8).tensor_layout();
        let mut rng = Rng::new(77);
        let current = TensorModel::random_init(&layout, &mut rng);
        let ms = (0..n)
            .map(|_| Arc::new(TensorModel::random_init(&layout, &mut rng)))
            .collect();
        (current, ms)
    }

    #[test]
    fn rule_factory_known_and_unknown() {
        for rule in ["fedavg", "fedadam", "fedyogi", "fedadagrad"] {
            let spec = AggregationSpec { rule: rule.into(), ..Default::default() };
            assert!(rule_from_spec(&spec).is_ok(), "{rule}");
        }
        let spec = AggregationSpec { rule: "bogus".into(), ..Default::default() };
        assert!(rule_from_spec(&spec).is_err());
    }

    #[test]
    fn contribution_validation() {
        let (current, ms) = models(2);
        let ok = vec![
            Contribution { model: Arc::clone(&ms[0]), weight: 1.0 },
            Contribution { model: Arc::clone(&ms[1]), weight: 2.0 },
        ];
        assert!(check_contributions(&current, &ok).is_ok());
        assert!(check_contributions(&current, &[]).is_err());
        let zero = vec![Contribution { model: Arc::clone(&ms[0]), weight: 0.0 }];
        assert!(check_contributions(&current, &zero).is_err());
        let neg = vec![
            Contribution { model: Arc::clone(&ms[0]), weight: 2.0 },
            Contribution { model: Arc::clone(&ms[1]), weight: -1.0 },
        ];
        assert!(check_contributions(&current, &neg).is_err());
        // Mismatched layout.
        let other = Arc::new(TensorModel::zeros(&ModelSpec::mlp(4, 2, 8).tensor_layout()));
        let bad = vec![Contribution { model: other, weight: 1.0 }];
        assert!(check_contributions(&current, &bad).is_err());
    }

    #[test]
    fn backend_from_spec() {
        let spec = AggregationSpec {
            backend: crate::config::AggregationBackend::Parallel,
            threads: 3,
            ..Default::default()
        };
        match Backend::from_spec(&spec) {
            Backend::Parallel(p) => assert_eq!(p.size(), 3),
            other => panic!("expected parallel, got {other:?}"),
        }
        let spec = AggregationSpec {
            backend: crate::config::AggregationBackend::Sequential,
            ..Default::default()
        };
        assert!(matches!(Backend::from_spec(&spec), Backend::Sequential));
        let spec = AggregationSpec {
            backend: crate::config::AggregationBackend::Chunked,
            threads: 2,
            ..Default::default()
        };
        match Backend::from_spec(&spec) {
            Backend::Chunked { pool, scratch } => {
                assert_eq!(pool.size(), 2);
                assert_eq!(scratch.fresh_allocations(), 0);
            }
            other => panic!("expected chunked, got {other:?}"),
        }
    }

    #[test]
    fn l2_norm_agrees_across_backends() {
        let (current, _) = models(1);
        let serial = current.l2_norm();
        let spec = AggregationSpec {
            backend: crate::config::AggregationBackend::Chunked,
            threads: 3,
            ..Default::default()
        };
        let chunked_backend = Backend::from_spec(&spec);
        for backend in [&Backend::Sequential, &chunked_backend] {
            let norm = model_l2_norm(&current, backend);
            assert!((norm - serial).abs() < 1e-9, "{norm} vs {serial} ({backend:?})");
        }
        // Chunk-ordered reduction ⇒ deterministic across repeated calls.
        let a = model_l2_norm(&current, &chunked_backend);
        let b = model_l2_norm(&current, &chunked_backend);
        assert_eq!(a.to_bits(), b.to_bits());
    }
}
