//! LRU-capped per-learner delta-base map.
//!
//! The controller pins, per learner id, the last model that learner
//! acknowledged over a lossless dispatch stream — the base its next
//! delta-coded exchange encodes against. In sync rounds every entry
//! aliases the one shared fan-out model (1 distinct model pinned), but
//! a large *async* fleet at divergent rounds — or learner churn with
//! fresh ids — can pin O(learners-ever-seen) distinct models. This map
//! bounds the number of **distinct pinned models**: when an insert
//! pushes the distinct count past the cap, least-recently-touched
//! entries are evicted until it fits. Evicted learners simply degrade
//! to a full-f32 send on their next dispatch (base miss → `NotFound` →
//! fallback), and deregistration drops the learner's entry outright.

use crate::tensor::TensorModel;
use std::collections::{HashMap, HashSet};
use std::sync::Arc;

/// Default cap on distinct pinned base models (a sync fleet uses 1; an
/// async fleet rarely has more than a handful of *live* divergent
/// rounds — anything beyond that is churn the map should shed).
pub const DEFAULT_BASE_MODEL_CAP: usize = 16;

struct BaseEntry {
    round: u64,
    model: Arc<TensorModel>,
    last_used: u64,
}

/// Per-learner `(round, model)` base map, LRU-bounded by distinct
/// pinned models. Callers wrap it in a `Mutex`; every operation is
/// O(entries) at worst (entry counts are per-registered-learner, small
/// next to any model).
pub struct BaseMap {
    cap_models: usize,
    tick: u64,
    entries: HashMap<String, BaseEntry>,
}

impl BaseMap {
    pub fn new(cap_models: usize) -> BaseMap {
        BaseMap { cap_models: cap_models.max(1), tick: 0, entries: HashMap::new() }
    }

    /// Look up a learner's base, marking it recently used.
    pub fn get(&mut self, learner_id: &str) -> Option<(u64, Arc<TensorModel>)> {
        self.tick += 1;
        let tick = self.tick;
        self.entries.get_mut(learner_id).map(|e| {
            e.last_used = tick;
            (e.round, Arc::clone(&e.model))
        })
    }

    /// Install a learner's base. Returns every model handle this insert
    /// displaced — the learner's previous base plus any LRU-evicted
    /// entries — so the caller can recycle uniquely-owned buffers into
    /// the scratch arena.
    pub fn insert(
        &mut self,
        learner_id: &str,
        round: u64,
        model: Arc<TensorModel>,
    ) -> Vec<Arc<TensorModel>> {
        self.tick += 1;
        let mut displaced = Vec::new();
        if let Some(old) = self.entries.insert(
            learner_id.to_string(),
            BaseEntry { round, model, last_used: self.tick },
        ) {
            displaced.push(old.model);
        }
        // Evict least-recently-used *models* (not entries) until the
        // distinct pinned count fits the cap: dropping an entry whose
        // model is still pinned by a fresher entry would cost that
        // learner its delta base without freeing anything. A model's
        // recency is the newest touch among the entries pinning it;
        // every entry of the LRU model goes together. The model just
        // inserted carries the newest tick, so it is evicted only if
        // the cap is impossible to satisfy otherwise (cap ≥ 1 makes
        // that unreachable).
        while self.distinct_models() > self.cap_models {
            let mut recency: HashMap<usize, u64> = HashMap::new();
            for e in self.entries.values() {
                let key = Arc::as_ptr(&e.model) as usize;
                let r = recency.entry(key).or_insert(0);
                *r = (*r).max(e.last_used);
            }
            let Some(victim) = recency.iter().min_by_key(|(_, r)| **r).map(|(k, _)| *k) else {
                break;
            };
            self.entries.retain(|_, e| {
                if Arc::as_ptr(&e.model) as usize == victim {
                    displaced.push(Arc::clone(&e.model));
                    false
                } else {
                    true
                }
            });
        }
        displaced
    }

    /// Drop a learner's entry (deregistration), returning its model
    /// handle for recycling.
    pub fn remove(&mut self, learner_id: &str) -> Option<Arc<TensorModel>> {
        self.entries.remove(learner_id).map(|e| e.model)
    }

    /// Number of per-learner entries (diagnostics/tests; the cap below
    /// bounds *models*, not entries).
    #[allow(dead_code)]
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    #[allow(dead_code)]
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Number of distinct models currently pinned (entries sharing an
    /// `Arc` count once — the sync-fleet case).
    pub fn distinct_models(&self) -> usize {
        self.entries
            .values()
            .map(|e| Arc::as_ptr(&e.model) as usize)
            .collect::<HashSet<usize>>()
            .len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelSpec;
    use crate::util::Rng;

    fn model(seed: u64) -> Arc<TensorModel> {
        let layout = ModelSpec::mlp(4, 1, 4).tensor_layout();
        Arc::new(TensorModel::random_init(&layout, &mut Rng::new(seed)))
    }

    #[test]
    fn aliased_entries_count_as_one_model() {
        let mut m = BaseMap::new(2);
        let shared = model(1);
        for i in 0..10 {
            assert!(m.insert(&format!("l{i}"), 1, Arc::clone(&shared)).is_empty());
        }
        // A whole sync fleet pins ONE distinct model: nothing evicted.
        assert_eq!(m.len(), 10);
        assert_eq!(m.distinct_models(), 1);
    }

    #[test]
    fn distinct_models_are_lru_capped() {
        let mut m = BaseMap::new(2);
        m.insert("a", 1, model(1));
        m.insert("b", 2, model(2));
        assert_eq!(m.distinct_models(), 2);
        // Touch `a` so `b` is the LRU entry.
        assert!(m.get("a").is_some());
        let displaced = m.insert("c", 3, model(3));
        assert_eq!(displaced.len(), 1, "one eviction expected");
        assert_eq!(m.distinct_models(), 2);
        assert!(m.get("b").is_none(), "LRU entry should be evicted");
        assert!(m.get("a").is_some());
        assert!(m.get("c").is_some());
    }

    #[test]
    fn eviction_targets_models_not_aliased_entries() {
        // a1 and a2 alias model A (a1 touched long ago); B is the true
        // LRU *model*. Inserting C must evict B's entry — evicting a1
        // would cost a learner its base without freeing anything.
        let mut m = BaseMap::new(2);
        let a = model(1);
        let b = model(2);
        m.insert("a1", 1, Arc::clone(&a));
        m.insert("b1", 1, Arc::clone(&b));
        m.insert("a2", 1, Arc::clone(&a));
        let displaced = m.insert("c", 1, model(3));
        assert_eq!(displaced.len(), 1);
        assert!(Arc::ptr_eq(&displaced[0], &b));
        assert!(m.get("a1").is_some(), "aliased entry evicted needlessly");
        assert!(m.get("a2").is_some());
        assert!(m.get("b1").is_none());
        assert_eq!(m.distinct_models(), 2);
    }

    #[test]
    fn insert_displaces_previous_entry_for_same_learner() {
        let mut m = BaseMap::new(4);
        let first = model(1);
        m.insert("a", 1, Arc::clone(&first));
        let displaced = m.insert("a", 2, model(2));
        assert_eq!(displaced.len(), 1);
        assert!(Arc::ptr_eq(&displaced[0], &first));
        assert_eq!(m.get("a").unwrap().0, 2);
    }

    #[test]
    fn remove_drops_the_entry() {
        let mut m = BaseMap::new(4);
        m.insert("a", 1, model(1));
        assert!(m.remove("a").is_some());
        assert!(m.remove("a").is_none());
        assert!(m.is_empty());
        assert_eq!(m.distinct_models(), 0);
    }

    #[test]
    fn cap_one_keeps_only_the_newest_model() {
        let mut m = BaseMap::new(1);
        for i in 0..5 {
            m.insert(&format!("l{i}"), i, model(i));
        }
        assert_eq!(m.distinct_models(), 1);
        assert!(m.get("l4").is_some());
    }
}
