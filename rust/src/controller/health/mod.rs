//! Fleet health: heartbeat-driven failure detection.
//!
//! The federation's control plane never learns about a dead peer from
//! the transport — a severed aggregator just stops answering. This
//! module turns the existing `Heartbeat`/`HeartbeatAck` RPC into a
//! failure detector: a prober (the driver's monitor for the root tier,
//! each aggregator for its shard) feeds every probe outcome into a
//! [`FailureDetector`], which classifies each peer as
//! [`PeerStatus::Alive`], [`PeerStatus::Suspect`] or
//! [`PeerStatus::Dead`] from two signals:
//!
//! * **Missed beats** — consecutive failed probes, the crash-stop
//!   signal. `suspect_after` misses raise suspicion, `dead_after`
//!   misses declare death (and the driver's failover path re-homes the
//!   dead aggregator's learners).
//! * **Ack silence** — time since the last successful ack, measured
//!   against an EWMA of the peer's observed inter-ack gap (floored at
//!   the probe interval). A peer whose acks historically arrive every
//!   5 s is not suspected after 3 s of silence just because the probe
//!   interval is 1 s.
//!
//! All time flows through the PR-8 [`Clock`] API, so the detector is
//! fully exercisable on a simulated clock: tests advance virtual time
//! and watch a peer decay Alive → Suspect → Dead in zero wall time.

use crate::util::{Clock, Timestamp};
use anyhow::{bail, Result};
use std::collections::HashMap;
use std::sync::Mutex;
use std::time::Duration;

/// The `health:` env block: probe cadence and failure thresholds.
///
/// ```yaml
/// health:
///   interval_ms: 1000   # probe period
///   suspect_after: 3    # consecutive misses -> Suspect
///   dead_after: 5       # consecutive misses -> Dead (failover fires)
///   ewma_alpha: 0.2     # inter-ack gap smoothing, in (0, 1]
/// ```
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HealthSpec {
    /// Heartbeat probe period in milliseconds.
    pub interval_ms: u64,
    /// Consecutive missed probes (or silence horizons) before a peer
    /// is suspected.
    pub suspect_after: u32,
    /// Consecutive missed probes (or silence horizons) before a peer
    /// is declared dead.
    pub dead_after: u32,
    /// EWMA smoothing factor for the observed inter-ack gap, in
    /// (0, 1]: higher adapts faster, lower remembers longer.
    pub ewma_alpha: f64,
}

impl Default for HealthSpec {
    fn default() -> HealthSpec {
        HealthSpec { interval_ms: 1000, suspect_after: 3, dead_after: 5, ewma_alpha: 0.2 }
    }
}

impl HealthSpec {
    /// Probe period as a [`Duration`].
    pub fn interval(&self) -> Duration {
        Duration::from_millis(self.interval_ms)
    }

    /// Check invariants (env loaders call this via
    /// [`crate::config::FederationEnv::validate`]).
    pub fn validate(&self) -> Result<()> {
        if self.interval_ms == 0 {
            bail!("health interval_ms must be >= 1");
        }
        if self.suspect_after == 0 {
            bail!("health suspect_after must be >= 1");
        }
        if self.dead_after < self.suspect_after {
            bail!("health dead_after must be >= suspect_after");
        }
        if !(self.ewma_alpha > 0.0 && self.ewma_alpha <= 1.0) {
            bail!("health ewma_alpha must be in (0, 1]");
        }
        Ok(())
    }
}

/// A peer's classification, ordered by severity.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum PeerStatus {
    /// Acks arriving (or no evidence yet): the peer participates.
    Alive,
    /// Enough misses/silence to stop trusting the peer, not enough to
    /// act — probing continues.
    Suspect,
    /// The peer is gone: failover may re-home its dependents.
    Dead,
}

#[derive(Debug, Default)]
struct PeerHealth {
    last_ack: Option<Timestamp>,
    /// EWMA of the inter-ack gap, seconds.
    ewma_gap: Option<f64>,
    /// Consecutive failed probes since the last successful ack.
    misses: u32,
    /// Acks that arrived but reported `healthy: false` (the peer is
    /// alive yet degraded — open rounds wedged, retries giving up).
    degraded_acks: u64,
}

/// Per-peer failure detector fed by heartbeat probe outcomes.
pub struct FailureDetector {
    spec: HealthSpec,
    clock: Clock,
    peers: Mutex<HashMap<String, PeerHealth>>,
}

impl FailureDetector {
    pub fn new(spec: HealthSpec, clock: Clock) -> FailureDetector {
        FailureDetector { spec, clock, peers: Mutex::new(HashMap::new()) }
    }

    pub fn spec(&self) -> &HealthSpec {
        &self.spec
    }

    /// Record a successful probe: any ack proves liveness (misses
    /// reset), and the inter-ack gap feeds the EWMA horizon. An ack
    /// with `healthy: false` still counts as alive — the peer is
    /// responding — but is tallied as degraded.
    pub fn observe_ack(&self, peer: &str, healthy: bool) {
        let now = self.clock.now();
        let mut peers = self.peers.lock().unwrap();
        let p = peers.entry(peer.to_string()).or_default();
        if let Some(last) = p.last_ack {
            let gap = now.saturating_sub(last).as_secs_f64();
            p.ewma_gap = Some(match p.ewma_gap {
                Some(e) => e + self.spec.ewma_alpha * (gap - e),
                None => gap,
            });
        }
        p.last_ack = Some(now);
        p.misses = 0;
        if !healthy {
            p.degraded_acks += 1;
        }
    }

    /// Record a failed probe (dial refused, transport error, timeout).
    pub fn observe_miss(&self, peer: &str) {
        let mut peers = self.peers.lock().unwrap();
        let p = peers.entry(peer.to_string()).or_default();
        p.misses = p.misses.saturating_add(1);
    }

    /// Classify `peer` now: the worst of the missed-beat count and the
    /// silence-vs-EWMA-horizon signal. Unknown peers are `Alive` (no
    /// evidence against them).
    pub fn status(&self, peer: &str) -> PeerStatus {
        let peers = self.peers.lock().unwrap();
        let Some(p) = peers.get(peer) else { return PeerStatus::Alive };
        let mut worst = PeerStatus::Alive;
        if p.misses >= self.spec.dead_after {
            return PeerStatus::Dead;
        }
        if p.misses >= self.spec.suspect_after {
            worst = PeerStatus::Suspect;
        }
        if let Some(last) = p.last_ack {
            // Silence horizon: the peer's own observed cadence, never
            // tighter than the configured probe interval.
            let horizon = self.spec.interval().as_secs_f64().max(p.ewma_gap.unwrap_or(0.0));
            let silence = self.clock.since(last).as_secs_f64();
            if silence >= horizon * f64::from(self.spec.dead_after) {
                return PeerStatus::Dead;
            }
            if silence >= horizon * f64::from(self.spec.suspect_after) {
                worst = worst.max(PeerStatus::Suspect);
            }
        }
        worst
    }

    /// How many of `peer`'s acks reported `healthy: false`.
    pub fn degraded_acks(&self, peer: &str) -> u64 {
        self.peers.lock().unwrap().get(peer).map_or(0, |p| p.degraded_acks)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> HealthSpec {
        HealthSpec { interval_ms: 1000, suspect_after: 2, dead_after: 4, ewma_alpha: 0.5 }
    }

    #[test]
    fn spec_defaults_validate_and_bad_specs_are_refused() {
        assert!(HealthSpec::default().validate().is_ok());
        assert_eq!(HealthSpec::default().interval(), Duration::from_millis(1000));
        for bad in [
            HealthSpec { interval_ms: 0, ..HealthSpec::default() },
            HealthSpec { suspect_after: 0, ..HealthSpec::default() },
            HealthSpec { suspect_after: 6, dead_after: 5, ..HealthSpec::default() },
            HealthSpec { ewma_alpha: 0.0, ..HealthSpec::default() },
            HealthSpec { ewma_alpha: 1.5, ..HealthSpec::default() },
        ] {
            assert!(bad.validate().is_err(), "{bad:?} should be rejected");
        }
    }

    #[test]
    fn consecutive_misses_decay_alive_suspect_dead_and_an_ack_resets() {
        let det = FailureDetector::new(spec(), Clock::sim());
        assert_eq!(det.status("agg-0"), PeerStatus::Alive, "no evidence yet");
        det.observe_miss("agg-0");
        assert_eq!(det.status("agg-0"), PeerStatus::Alive);
        det.observe_miss("agg-0");
        assert_eq!(det.status("agg-0"), PeerStatus::Suspect);
        det.observe_miss("agg-0");
        assert_eq!(det.status("agg-0"), PeerStatus::Suspect);
        det.observe_miss("agg-0");
        assert_eq!(det.status("agg-0"), PeerStatus::Dead);
        // A suspect peer that answers again is rehabilitated in one ack.
        let det = FailureDetector::new(spec(), Clock::sim());
        det.observe_miss("agg-1");
        det.observe_miss("agg-1");
        assert_eq!(det.status("agg-1"), PeerStatus::Suspect);
        det.observe_ack("agg-1", true);
        assert_eq!(det.status("agg-1"), PeerStatus::Alive);
    }

    #[test]
    fn silence_on_the_sim_clock_kills_without_a_single_probe_miss() {
        // Pure time-based decay, zero wall time: the peer acked once,
        // then went silent. suspect at 2x interval, dead at 4x.
        let clock = Clock::sim();
        let det = FailureDetector::new(spec(), clock.clone());
        det.observe_ack("agg-0", true);
        assert_eq!(det.status("agg-0"), PeerStatus::Alive);
        clock.advance_to(Duration::from_millis(1999));
        assert_eq!(det.status("agg-0"), PeerStatus::Alive);
        clock.advance_to(Duration::from_millis(2000));
        assert_eq!(det.status("agg-0"), PeerStatus::Suspect);
        clock.advance_to(Duration::from_millis(3999));
        assert_eq!(det.status("agg-0"), PeerStatus::Suspect);
        clock.advance_to(Duration::from_millis(4000));
        assert_eq!(det.status("agg-0"), PeerStatus::Dead);
    }

    #[test]
    fn ewma_gap_widens_the_silence_horizon_for_slow_but_steady_peers() {
        // A peer that acks every 5 s (probe interval 1 s) must not be
        // suspected after 2 s of silence — its own cadence is the
        // horizon. With ewma_alpha 0.5 and three 5 s gaps the EWMA sits
        // at 5 s, so suspicion starts at 10 s of silence, death at 20.
        let clock = Clock::sim();
        let det = FailureDetector::new(spec(), clock.clone());
        for i in 0..4u64 {
            clock.advance_to(Duration::from_secs(5 * i));
            det.observe_ack("slow", true);
        }
        // 6 s of silence: way past 2x the probe interval, well inside
        // 2x the observed cadence.
        clock.advance_to(Duration::from_secs(15 + 6));
        assert_eq!(det.status("slow"), PeerStatus::Alive);
        clock.advance_to(Duration::from_secs(15 + 10));
        assert_eq!(det.status("slow"), PeerStatus::Suspect);
        clock.advance_to(Duration::from_secs(15 + 20));
        assert_eq!(det.status("slow"), PeerStatus::Dead);
    }

    #[test]
    fn degraded_acks_count_but_do_not_kill() {
        let clock = Clock::sim();
        let det = FailureDetector::new(spec(), clock.clone());
        det.observe_ack("learner-3", false);
        det.observe_ack("learner-3", false);
        det.observe_ack("learner-3", true);
        assert_eq!(det.degraded_acks("learner-3"), 2);
        // The peer answers, so it is alive — degradation is a signal
        // for operators, not a death sentence.
        assert_eq!(det.status("learner-3"), PeerStatus::Alive);
        assert_eq!(det.degraded_acks("unknown"), 0);
    }
}
