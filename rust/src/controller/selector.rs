//! Learner selection policies for training/evaluation rounds.
//!
//! The paper's stress tests run with all learners participating every
//! round ([`Selector::All`]); [`Selector::RandomFraction`] implements the
//! standard client-sampling used in cross-device settings,
//! [`Selector::FreshnessAware`] prefers learners whose last contribution
//! is oldest (useful under the async protocol to balance staleness), and
//! [`Selector::PacingAware`] biases selection by the pacing subsystem's
//! per-learner profiles (fast/reliable learners first) while a freshness
//! floor guarantees slow sites still contribute.

use crate::util::Rng;
use std::collections::HashMap;

/// Inputs a selection decision may consult, assembled by the controller
/// from its round bookkeeping and the pacing registry.
pub struct SelectionCtx<'a> {
    /// Learner id → last round it participated (missing = never).
    pub last_round: &'a HashMap<String, u64>,
    /// Learner id → pacing score (`throughput × reliability`; missing =
    /// no profile yet).
    pub scores: &'a HashMap<String, f64>,
    /// The round being selected for.
    pub round: u64,
}

impl<'a> SelectionCtx<'a> {
    /// Freshness sort key: `None` (never participated) orders before
    /// every `Some(round)` — fresh learners always sort first.
    fn freshness_key(&self, id: &str) -> Option<u64> {
        self.last_round.get(id).copied()
    }
}

/// Selection policy.
#[derive(Debug, Clone)]
pub enum Selector {
    /// Every registered learner (the paper's evaluation setting).
    All,
    /// A uniform random fraction in (0, 1], at least one learner.
    RandomFraction(f64),
    /// The `k` learners with the oldest last-participation round
    /// (never-participated learners first).
    FreshnessAware { k: usize },
    /// The `k` best learners by pacing score, with a freshness floor:
    /// learners idle for at least `freshness_rounds` rounds (or never
    /// scheduled) are force-included ahead of the score ranking.
    PacingAware { k: usize, freshness_rounds: u64 },
}

impl Selector {
    /// Choose participant ids out of `learner_ids`.
    ///
    /// `ctx` carries participation history and pacing scores; `rng`
    /// drives the random policy deterministically.
    pub fn select(
        &self,
        learner_ids: &[String],
        ctx: &SelectionCtx<'_>,
        rng: &mut Rng,
    ) -> Vec<String> {
        match self {
            Selector::All => learner_ids.to_vec(),
            Selector::RandomFraction(f) => {
                let k = ((learner_ids.len() as f64 * f).ceil() as usize)
                    .clamp(1, learner_ids.len());
                rng.sample_indices(learner_ids.len(), k)
                    .into_iter()
                    .map(|i| learner_ids[i].clone())
                    .collect()
            }
            Selector::FreshnessAware { k } => {
                let k = (*k).clamp(1, learner_ids.len());
                // `Option` ordering (None < Some) distinguishes "never
                // participated" from "participated at round 0".
                let mut scored: Vec<(Option<u64>, &String)> =
                    learner_ids.iter().map(|id| (ctx.freshness_key(id), id)).collect();
                scored.sort_by(|a, b| a.0.cmp(&b.0).then_with(|| a.1.cmp(b.1)));
                scored.into_iter().take(k).map(|(_, id)| id.clone()).collect()
            }
            Selector::PacingAware { k, freshness_rounds } => {
                let k = (*k).clamp(1, learner_ids.len());
                let stale = |id: &String| match ctx.freshness_key(id) {
                    None => true,
                    Some(last) => ctx.round.saturating_sub(last) >= *freshness_rounds,
                };
                // Freshness floor first: stale learners, oldest first,
                // fill slots before any score ranking — a 10×-slow site
                // still contributes every `freshness_rounds` rounds.
                let mut forced: Vec<(Option<u64>, &String)> = learner_ids
                    .iter()
                    .filter(|id| stale(id))
                    .map(|id| (ctx.freshness_key(id), id))
                    .collect();
                forced.sort_by(|a, b| a.0.cmp(&b.0).then_with(|| a.1.cmp(b.1)));
                let mut chosen: Vec<String> =
                    forced.into_iter().take(k).map(|(_, id)| id.clone()).collect();
                if chosen.len() < k {
                    // Remaining slots go to the fastest/most reliable
                    // profiled learners (unprofiled ids score 0 and are
                    // deterministically last, by id).
                    let mut rest: Vec<(f64, &String)> = learner_ids
                        .iter()
                        .filter(|id| !chosen.iter().any(|c| c == *id))
                        .map(|id| (ctx.scores.get(id).copied().unwrap_or(0.0), id))
                        .collect();
                    rest.sort_by(|a, b| {
                        b.0.partial_cmp(&a.0)
                            .unwrap_or(std::cmp::Ordering::Equal)
                            .then_with(|| a.1.cmp(b.1))
                    });
                    let need = k - chosen.len();
                    chosen.extend(rest.into_iter().take(need).map(|(_, id)| id.clone()));
                }
                chosen
            }
        }
    }

    /// Build from a participation fraction (env config convenience).
    pub fn from_participation(p: f64) -> Selector {
        if (p - 1.0).abs() < f64::EPSILON {
            Selector::All
        } else {
            Selector::RandomFraction(p)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ids(n: usize) -> Vec<String> {
        (0..n).map(|i| format!("l{i}")).collect()
    }

    fn ctx<'a>(
        last: &'a HashMap<String, u64>,
        scores: &'a HashMap<String, f64>,
        round: u64,
    ) -> SelectionCtx<'a> {
        SelectionCtx { last_round: last, scores, round }
    }

    fn empty_select(sel: &Selector, l: &[String], seed: u64) -> Vec<String> {
        let (last, scores) = (HashMap::new(), HashMap::new());
        sel.select(l, &ctx(&last, &scores, 1), &mut Rng::new(seed))
    }

    #[test]
    fn all_selects_everyone_in_order() {
        let l = ids(5);
        assert_eq!(empty_select(&Selector::All, &l, 0), l);
    }

    #[test]
    fn fraction_selects_expected_count_distinct() {
        let l = ids(10);
        let sel = empty_select(&Selector::RandomFraction(0.3), &l, 1);
        assert_eq!(sel.len(), 3);
        let mut d = sel.clone();
        d.sort();
        d.dedup();
        assert_eq!(d.len(), 3);
        // At least one learner even for tiny fractions.
        let sel = empty_select(&Selector::RandomFraction(0.01), &l, 2);
        assert_eq!(sel.len(), 1);
    }

    #[test]
    fn fraction_is_deterministic_per_seed() {
        let l = ids(20);
        let a = empty_select(&Selector::RandomFraction(0.5), &l, 9);
        let b = empty_select(&Selector::RandomFraction(0.5), &l, 9);
        assert_eq!(a, b);
    }

    #[test]
    fn freshness_prefers_oldest() {
        let l = ids(4);
        let mut last = HashMap::new();
        last.insert("l0".to_string(), 10u64);
        last.insert("l1".to_string(), 2);
        last.insert("l2".to_string(), 7);
        // l3 never participated → first choice.
        let scores = HashMap::new();
        let sel = Selector::FreshnessAware { k: 2 }.select(
            &l,
            &ctx(&last, &scores, 11),
            &mut Rng::new(0),
        );
        assert_eq!(sel, vec!["l3".to_string(), "l1".to_string()]);
    }

    #[test]
    fn freshness_distinguishes_never_from_round_zero() {
        // "a" participated at round 0; "b" never did. The old
        // `unwrap_or(0)` conflated the two and picked "a" on the id
        // tiebreak — Option ordering must pick "b".
        let l = vec!["a".to_string(), "b".to_string()];
        let mut last = HashMap::new();
        last.insert("a".to_string(), 0u64);
        let scores = HashMap::new();
        let sel = Selector::FreshnessAware { k: 1 }.select(
            &l,
            &ctx(&last, &scores, 1),
            &mut Rng::new(0),
        );
        assert_eq!(sel, vec!["b".to_string()]);
    }

    #[test]
    fn pacing_ranks_by_score() {
        let l = ids(4);
        let mut last = HashMap::new();
        let mut scores = HashMap::new();
        for (i, id) in l.iter().enumerate() {
            last.insert(id.clone(), 5); // everyone fresh
            scores.insert(id.clone(), i as f64);
        }
        let sel = Selector::PacingAware { k: 2, freshness_rounds: 10 }.select(
            &l,
            &ctx(&last, &scores, 6),
            &mut Rng::new(0),
        );
        // Highest scores win when nobody is stale.
        assert_eq!(sel, vec!["l3".to_string(), "l2".to_string()]);
    }

    #[test]
    fn pacing_freshness_floor_forces_stale_learners_in() {
        let l = ids(4);
        let mut last = HashMap::new();
        let mut scores = HashMap::new();
        // l0 is the fastest but l1 has been idle for 6 rounds and l3
        // has never participated: both pre-empt the score ranking.
        last.insert("l0".to_string(), 9u64);
        last.insert("l1".to_string(), 4);
        last.insert("l2".to_string(), 9);
        scores.insert("l0".to_string(), 100.0);
        scores.insert("l1".to_string(), 1.0);
        scores.insert("l2".to_string(), 50.0);
        let sel = Selector::PacingAware { k: 3, freshness_rounds: 5 }.select(
            &l,
            &ctx(&last, &scores, 10),
            &mut Rng::new(0),
        );
        // Stale first (never-participated l3, then oldest l1), then the
        // best score (l0).
        assert_eq!(sel, vec!["l3".to_string(), "l1".to_string(), "l0".to_string()]);
    }

    #[test]
    fn pacing_unprofiled_learners_are_stale_and_included() {
        // A brand-new learner has no last_round and no score: the
        // freshness floor (not the 0 score) is what schedules it.
        let l = ids(3);
        let mut last = HashMap::new();
        let mut scores = HashMap::new();
        last.insert("l0".to_string(), 5u64);
        last.insert("l1".to_string(), 5);
        scores.insert("l0".to_string(), 10.0);
        scores.insert("l1".to_string(), 20.0);
        let sel = Selector::PacingAware { k: 1, freshness_rounds: 4 }.select(
            &l,
            &ctx(&last, &scores, 6),
            &mut Rng::new(0),
        );
        assert_eq!(sel, vec!["l2".to_string()]);
    }

    #[test]
    fn from_participation_maps_one_to_all() {
        assert!(matches!(Selector::from_participation(1.0), Selector::All));
        assert!(matches!(Selector::from_participation(0.5), Selector::RandomFraction(_)));
    }
}
