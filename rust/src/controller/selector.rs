//! Learner selection policies for training/evaluation rounds.
//!
//! The paper's stress tests run with all learners participating every
//! round ([`Selector::All`]); [`Selector::RandomFraction`] implements the
//! standard client-sampling used in cross-device settings, and
//! [`Selector::FreshnessAware`] prefers learners whose last contribution
//! is oldest (useful under the async protocol to balance staleness).

use crate::util::Rng;
use std::collections::HashMap;

/// Selection policy.
#[derive(Debug, Clone)]
pub enum Selector {
    /// Every registered learner (the paper's evaluation setting).
    All,
    /// A uniform random fraction in (0, 1], at least one learner.
    RandomFraction(f64),
    /// The `k` learners with the oldest last-participation round.
    FreshnessAware { k: usize },
}

impl Selector {
    /// Choose participant indices out of `learner_ids`.
    ///
    /// `last_round` maps learner id → last round it contributed (missing =
    /// never). `rng` drives the random policy deterministically.
    pub fn select(
        &self,
        learner_ids: &[String],
        last_round: &HashMap<String, u64>,
        rng: &mut Rng,
    ) -> Vec<String> {
        match self {
            Selector::All => learner_ids.to_vec(),
            Selector::RandomFraction(f) => {
                let k = ((learner_ids.len() as f64 * f).ceil() as usize)
                    .clamp(1, learner_ids.len());
                rng.sample_indices(learner_ids.len(), k)
                    .into_iter()
                    .map(|i| learner_ids[i].clone())
                    .collect()
            }
            Selector::FreshnessAware { k } => {
                let k = (*k).clamp(1, learner_ids.len());
                let mut scored: Vec<(u64, &String)> = learner_ids
                    .iter()
                    .map(|id| (last_round.get(id).copied().unwrap_or(0), id))
                    .collect();
                scored.sort_by(|a, b| a.0.cmp(&b.0).then_with(|| a.1.cmp(b.1)));
                scored.into_iter().take(k).map(|(_, id)| id.clone()).collect()
            }
        }
    }

    /// Build from a participation fraction (env config convenience).
    pub fn from_participation(p: f64) -> Selector {
        if (p - 1.0).abs() < f64::EPSILON {
            Selector::All
        } else {
            Selector::RandomFraction(p)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ids(n: usize) -> Vec<String> {
        (0..n).map(|i| format!("l{i}")).collect()
    }

    #[test]
    fn all_selects_everyone_in_order() {
        let l = ids(5);
        let sel = Selector::All.select(&l, &HashMap::new(), &mut Rng::new(0));
        assert_eq!(sel, l);
    }

    #[test]
    fn fraction_selects_expected_count_distinct() {
        let l = ids(10);
        let sel = Selector::RandomFraction(0.3).select(&l, &HashMap::new(), &mut Rng::new(1));
        assert_eq!(sel.len(), 3);
        let mut d = sel.clone();
        d.sort();
        d.dedup();
        assert_eq!(d.len(), 3);
        // At least one learner even for tiny fractions.
        let sel = Selector::RandomFraction(0.01).select(&l, &HashMap::new(), &mut Rng::new(2));
        assert_eq!(sel.len(), 1);
    }

    #[test]
    fn fraction_is_deterministic_per_seed() {
        let l = ids(20);
        let a = Selector::RandomFraction(0.5).select(&l, &HashMap::new(), &mut Rng::new(9));
        let b = Selector::RandomFraction(0.5).select(&l, &HashMap::new(), &mut Rng::new(9));
        assert_eq!(a, b);
    }

    #[test]
    fn freshness_prefers_oldest() {
        let l = ids(4);
        let mut last = HashMap::new();
        last.insert("l0".to_string(), 10u64);
        last.insert("l1".to_string(), 2);
        last.insert("l2".to_string(), 7);
        // l3 never participated → round 0 → first choice.
        let sel = Selector::FreshnessAware { k: 2 }.select(&l, &last, &mut Rng::new(0));
        assert_eq!(sel, vec!["l3".to_string(), "l1".to_string()]);
    }

    #[test]
    fn from_participation_maps_one_to_all() {
        assert!(matches!(Selector::from_participation(1.0), Selector::All));
        assert!(matches!(Selector::from_participation(0.5), Selector::RandomFraction(_)));
    }
}
