//! On-disk model store (§5 future work: "different model stores (e.g.
//! distributed key-value or on-disk model stores)").
//!
//! Each entry is one file `<dir>/<learner>/<round>.model` containing the
//! wire encoding of the model (`ModelProto`) prefixed by a small metadata
//! record. An in-memory index mirrors the directory so `latest()` is one
//! file read; `insert()` is one file write.

use super::{ModelStore, StoredModel};
use crate::proto::wire::{WireReader, WireWriter};
use crate::proto::{ModelProto, TaskMeta};
use crate::tensor::{ByteOrder, DType};
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::path::PathBuf;

/// File-per-model store rooted at a directory.
pub struct OnDiskStore {
    root: PathBuf,
    /// learner → sorted rounds present on disk.
    index: HashMap<String, Vec<u64>>,
    bytes: usize,
    entries: usize,
}

impl OnDiskStore {
    /// Open (and create) a store rooted at `dir`. Existing files are
    /// re-indexed, so a store survives controller restarts.
    pub fn open(dir: impl Into<PathBuf>) -> Result<OnDiskStore> {
        let root = dir.into();
        std::fs::create_dir_all(&root).with_context(|| format!("create {root:?}"))?;
        let mut store =
            OnDiskStore { root: root.clone(), index: HashMap::new(), bytes: 0, entries: 0 };
        for learner_dir in std::fs::read_dir(&root)? {
            let learner_dir = learner_dir?;
            if !learner_dir.file_type()?.is_dir() {
                continue;
            }
            let learner = learner_dir.file_name().to_string_lossy().to_string();
            for f in std::fs::read_dir(learner_dir.path())? {
                let f = f?;
                let name = f.file_name().to_string_lossy().to_string();
                if let Some(round) = name.strip_suffix(".model").and_then(|s| s.parse().ok()) {
                    store.index.entry(learner.clone()).or_default().push(round);
                    store.bytes += f.metadata()?.len() as usize;
                    store.entries += 1;
                }
            }
        }
        for v in store.index.values_mut() {
            v.sort_unstable();
        }
        Ok(store)
    }

    fn path_for(&self, learner: &str, round: u64) -> PathBuf {
        self.root.join(learner).join(format!("{round}.model"))
    }

    fn write_entry(&self, entry: &StoredModel) -> Result<usize> {
        let mut w = WireWriter::with_capacity(entry.model.byte_size_f32() + 256);
        w.put_str(&entry.learner_id);
        w.put_varint(entry.round);
        w.put_varint(entry.meta.train_time_per_batch_us);
        w.put_varint(entry.meta.completed_steps as u64);
        w.put_varint(entry.meta.completed_epochs as u64);
        w.put_varint(entry.meta.num_samples as u64);
        w.put_f64(entry.meta.train_loss);
        let proto = ModelProto::from_model(&entry.model, DType::F32, ByteOrder::Little);
        let model_bytes = crate::proto::Message::ShipModel { model: proto }.encode();
        w.put_bytes(&model_bytes);
        // v5 telemetry tail AFTER the model payload, mirroring the wire
        // codec's tolerance trick: files written before these fields
        // existed simply end at `model_bytes` (read as zeros), and
        // older binaries reading new files ignore the trailing bytes —
        // restart survival holds in both directions.
        w.put_f64(entry.meta.steps_per_sec);
        w.put_varint(entry.meta.train_wall_time_us);
        let bytes = w.into_bytes();
        let path = self.path_for(&entry.learner_id, entry.round);
        std::fs::create_dir_all(path.parent().unwrap())?;
        std::fs::write(&path, &bytes).with_context(|| format!("write {path:?}"))?;
        Ok(bytes.len())
    }

    fn read_entry(&self, learner: &str, round: u64) -> Result<StoredModel> {
        let path = self.path_for(learner, round);
        let bytes = std::fs::read(&path).with_context(|| format!("read {path:?}"))?;
        let mut r = WireReader::new(&bytes);
        let learner_id = r.get_str()?;
        let round = r.get_varint()?;
        let mut meta = TaskMeta {
            train_time_per_batch_us: r.get_varint()?,
            completed_steps: r.get_varint()? as usize,
            completed_epochs: r.get_varint()? as usize,
            num_samples: r.get_varint()? as usize,
            train_loss: r.get_f64()?,
            ..Default::default()
        };
        let model_bytes = r.get_bytes()?;
        let model = match crate::proto::Message::decode(model_bytes)? {
            crate::proto::Message::ShipModel { model } => model.to_model()?,
            other => anyhow::bail!("unexpected stored message {}", other.kind()),
        };
        // Telemetry tail (absent in files written before v5).
        if !r.is_done() {
            meta.steps_per_sec = r.get_f64()?;
            meta.train_wall_time_us = r.get_varint()?;
        }
        Ok(StoredModel { learner_id, round, meta, model: std::sync::Arc::new(model) })
    }
}

impl ModelStore for OnDiskStore {
    fn insert(&mut self, entry: StoredModel) -> Result<()> {
        let n = self.write_entry(&entry)?;
        let rounds = self.index.entry(entry.learner_id.clone()).or_default();
        match rounds.binary_search(&entry.round) {
            Ok(_) => {} // overwrite, no index/entry change (bytes may drift slightly)
            Err(pos) => {
                rounds.insert(pos, entry.round);
                self.entries += 1;
                self.bytes += n;
            }
        }
        Ok(())
    }

    fn latest(&self, learner_id: &str) -> Result<Option<StoredModel>> {
        match self.index.get(learner_id).and_then(|v| v.last().copied()) {
            Some(round) => Ok(Some(self.read_entry(learner_id, round)?)),
            None => Ok(None),
        }
    }

    fn len(&self) -> usize {
        self.entries
    }

    fn byte_size(&self) -> usize {
        self.bytes
    }

    fn evict(&mut self, keep_last: usize) -> Result<Vec<StoredModel>> {
        // Entries live on disk, not in memory: nothing to hand back for
        // buffer recycling — deletion is the whole eviction.
        for (learner, rounds) in self.index.iter_mut() {
            while rounds.len() > keep_last {
                let round = rounds.remove(0);
                let path = self.root.join(learner).join(format!("{round}.model"));
                if let Ok(md) = std::fs::metadata(&path) {
                    self.bytes = self.bytes.saturating_sub(md.len() as usize);
                }
                std::fs::remove_file(&path).ok();
                self.entries -= 1;
            }
        }
        Ok(Vec::new())
    }

    fn name(&self) -> &'static str {
        "disk"
    }
}

#[cfg(test)]
mod tests {
    use super::super::test_support;
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("metisfl-store-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn conformance() {
        let dir = tmpdir("conf");
        let mut s = OnDiskStore::open(&dir).unwrap();
        test_support::conformance(&mut s);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn survives_reopen() {
        let dir = tmpdir("reopen");
        {
            let mut s = OnDiskStore::open(&dir).unwrap();
            s.insert(test_support::entry("a", 0, 1)).unwrap();
            s.insert(test_support::entry("a", 2, 2)).unwrap();
            s.insert(test_support::entry("b", 1, 3)).unwrap();
        }
        let s = OnDiskStore::open(&dir).unwrap();
        assert_eq!(s.len(), 3);
        assert_eq!(s.latest("a").unwrap().unwrap().round, 2);
        assert_eq!(s.latest("b").unwrap().unwrap().round, 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn overwrite_same_round_is_idempotent_in_index() {
        let dir = tmpdir("ow");
        let mut s = OnDiskStore::open(&dir).unwrap();
        s.insert(test_support::entry("a", 0, 1)).unwrap();
        s.insert(test_support::entry("a", 0, 99)).unwrap();
        assert_eq!(s.len(), 1);
        std::fs::remove_dir_all(&dir).ok();
    }
}
