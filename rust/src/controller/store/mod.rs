//! Learner model stores.
//!
//! §4 assumes "all local models fit in the controller's in-memory store
//! (e.g., hash map)" — that is [`InMemoryStore`]. §5's future work asks
//! for alternative stores when they do not fit; [`OnDiskStore`] implements
//! the on-disk variant behind the same trait so the trade-off can be
//! benchmarked (`benches/agg_ablation.rs` has a store comparison).

pub mod disk;
pub mod memory;

pub use disk::OnDiskStore;
pub use memory::InMemoryStore;

use crate::proto::TaskMeta;
use crate::tensor::TensorModel;
use anyhow::Result;
use std::sync::Arc;

/// A stored model plus its provenance.
///
/// The model is held by `Arc`: cloning a `StoredModel` (to hand a round's
/// selection to the aggregator, or to keep a lineage entry alive) copies
/// a pointer plus small metadata, never the parameter buffers — the
/// store is zero-copy on the aggregation hot path.
#[derive(Debug, Clone)]
pub struct StoredModel {
    pub learner_id: String,
    pub round: u64,
    pub meta: TaskMeta,
    pub model: Arc<TensorModel>,
}

/// Storage for learners' local models (insert on `MarkTaskCompleted`,
/// select at aggregation — T4–T7 in Fig. 1).
pub trait ModelStore: Send {
    /// Insert a completed local model (replaces/extends that learner's
    /// lineage per the implementation's policy).
    fn insert(&mut self, entry: StoredModel) -> Result<()>;

    /// Latest model for one learner.
    fn latest(&self, learner_id: &str) -> Result<Option<StoredModel>>;

    /// Latest models for a set of learners (selection step). Learners
    /// with no stored model are skipped.
    fn select_latest(&self, learner_ids: &[String]) -> Result<Vec<StoredModel>> {
        let mut out = Vec::with_capacity(learner_ids.len());
        for id in learner_ids {
            if let Some(m) = self.latest(id)? {
                out.push(m);
            }
        }
        Ok(out)
    }

    /// Number of stored models (across lineages).
    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total stored payload bytes (f32 accounting).
    fn byte_size(&self) -> usize;

    /// Remove everything older than `keep_last` entries per learner,
    /// returning the evicted entries still held in memory so the caller
    /// can recycle their buffers (e.g. into the aggregation scratch
    /// arena). Stores whose entries do not live in memory (disk) return
    /// only what they can hand back.
    fn evict(&mut self, keep_last: usize) -> Result<Vec<StoredModel>>;

    fn name(&self) -> &'static str;
}

#[cfg(test)]
pub(crate) mod test_support {
    use super::*;
    use crate::config::ModelSpec;
    use crate::util::Rng;

    pub fn entry(learner: &str, round: u64, seed: u64) -> StoredModel {
        let layout = ModelSpec::mlp(4, 2, 8).tensor_layout();
        let mut rng = Rng::new(seed);
        StoredModel {
            learner_id: learner.to_string(),
            round,
            meta: TaskMeta { num_samples: 100, ..Default::default() },
            model: Arc::new(TensorModel::random_init(&layout, &mut rng)),
        }
    }

    /// Conformance suite run against both store implementations.
    pub fn conformance(store: &mut dyn ModelStore) {
        assert!(store.is_empty());
        store.insert(entry("a", 0, 1)).unwrap();
        store.insert(entry("b", 0, 2)).unwrap();
        store.insert(entry("a", 1, 3)).unwrap();
        assert_eq!(store.len(), 3);
        assert!(store.byte_size() > 0);

        // latest() returns the newest round.
        let a = store.latest("a").unwrap().unwrap();
        assert_eq!(a.round, 1);
        assert!(store.latest("nobody").unwrap().is_none());

        // select_latest skips unknown learners.
        let sel = store
            .select_latest(&["a".into(), "zzz".into(), "b".into()])
            .unwrap();
        assert_eq!(sel.len(), 2);
        assert_eq!(sel[0].learner_id, "a");
        assert_eq!(sel[0].round, 1);

        // Eviction keeps the most recent per learner and returns what
        // it removed (in-memory stores hand the entries back for buffer
        // recycling; the disk store has nothing in memory to return).
        let evicted = store.evict(1).unwrap();
        if store.name() == "memory" {
            assert_eq!(evicted.len(), 1);
            assert_eq!(evicted[0].learner_id, "a");
            assert_eq!(evicted[0].round, 0);
        }
        assert_eq!(store.len(), 2);
        assert_eq!(store.latest("a").unwrap().unwrap().round, 1);

        // Models roundtrip exactly.
        let fresh = entry("c", 5, 9);
        store.insert(fresh.clone()).unwrap();
        let got = store.latest("c").unwrap().unwrap();
        assert_eq!(got.model, fresh.model);
        assert_eq!(got.meta.num_samples, 100);
    }
}
