//! In-memory hash-map model store (the paper's §4 baseline assumption).
//!
//! Lineages are kept **insertion-ordered by round** (insert finds its
//! slot via partition point, ties land after their equals), so
//! `latest()` is `last()` — O(1) plus an `Arc` clone — and eviction is a
//! front drain, instead of the seed's re-sort-on-every-evict and
//! full-scan `max_by_key` per `latest()` call.

use super::{ModelStore, StoredModel};
use anyhow::Result;
use std::collections::HashMap;

/// Hash-map store with per-learner lineage.
#[derive(Default)]
pub struct InMemoryStore {
    by_learner: HashMap<String, Vec<StoredModel>>,
}

impl InMemoryStore {
    pub fn new() -> InMemoryStore {
        Self::default()
    }

    /// Full lineage for one learner, oldest→newest round.
    pub fn lineage(&self, learner_id: &str) -> &[StoredModel] {
        self.by_learner.get(learner_id).map(|v| v.as_slice()).unwrap_or(&[])
    }

    pub fn learner_count(&self) -> usize {
        self.by_learner.len()
    }
}

impl ModelStore for InMemoryStore {
    fn insert(&mut self, entry: StoredModel) -> Result<()> {
        let lineage = self.by_learner.entry(entry.learner_id.clone()).or_default();
        // Sorted insert; `<=` sends same-round re-submissions after their
        // predecessors, preserving the old "latest wins" tie-break.
        let pos = lineage.partition_point(|m| m.round <= entry.round);
        lineage.insert(pos, entry);
        Ok(())
    }

    fn latest(&self, learner_id: &str) -> Result<Option<StoredModel>> {
        Ok(self.by_learner.get(learner_id).and_then(|v| v.last()).cloned())
    }

    fn len(&self) -> usize {
        self.by_learner.values().map(|v| v.len()).sum()
    }

    fn byte_size(&self) -> usize {
        self.by_learner
            .values()
            .flat_map(|v| v.iter())
            .map(|m| m.model.byte_size_f32())
            .sum()
    }

    fn evict(&mut self, keep_last: usize) -> Result<Vec<StoredModel>> {
        let mut evicted = Vec::new();
        for v in self.by_learner.values_mut() {
            // Already round-ordered: drop the oldest prefix in one drain,
            // handing the entries back so their buffers can be recycled.
            let excess = v.len().saturating_sub(keep_last);
            evicted.extend(v.drain(..excess));
        }
        Ok(evicted)
    }

    fn name(&self) -> &'static str {
        "memory"
    }
}

#[cfg(test)]
mod tests {
    use super::super::test_support;
    use super::*;

    #[test]
    fn conformance() {
        let mut s = InMemoryStore::new();
        test_support::conformance(&mut s);
    }

    #[test]
    fn out_of_order_inserts_keep_lineage_sorted() {
        let mut s = InMemoryStore::new();
        for round in [5u64, 1, 3, 2, 4] {
            s.insert(test_support::entry("x", round, round)).unwrap();
        }
        let rounds: Vec<u64> = s.lineage("x").iter().map(|m| m.round).collect();
        assert_eq!(rounds, vec![1, 2, 3, 4, 5]);
        assert_eq!(s.latest("x").unwrap().unwrap().round, 5);
    }

    #[test]
    fn same_round_resubmission_latest_wins() {
        let mut s = InMemoryStore::new();
        s.insert(test_support::entry("x", 7, 1)).unwrap();
        let second = test_support::entry("x", 7, 2);
        let expect = second.model.clone();
        s.insert(second).unwrap();
        // Ties are ordered by insertion: the re-submission is "latest".
        let got = s.latest("x").unwrap().unwrap();
        assert_eq!(got.model, expect);
        assert_eq!(s.len(), 2);
    }

    #[test]
    fn lineage_grows_and_evicts_in_round_order() {
        let mut s = InMemoryStore::new();
        for round in [3u64, 1, 2] {
            s.insert(test_support::entry("x", round, round)).unwrap();
        }
        assert_eq!(s.lineage("x").len(), 3);
        assert_eq!(s.latest("x").unwrap().unwrap().round, 3);
        s.evict(2).unwrap();
        let rounds: Vec<u64> = s.lineage("x").iter().map(|m| m.round).collect();
        assert_eq!(rounds, vec![2, 3]);
        assert_eq!(s.learner_count(), 1);
    }
}
