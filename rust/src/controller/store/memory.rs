//! In-memory hash-map model store (the paper's §4 baseline assumption).

use super::{ModelStore, StoredModel};
use anyhow::Result;
use std::collections::HashMap;

/// Hash-map store with per-learner lineage.
#[derive(Default)]
pub struct InMemoryStore {
    by_learner: HashMap<String, Vec<StoredModel>>,
}

impl InMemoryStore {
    pub fn new() -> InMemoryStore {
        Self::default()
    }

    /// Full lineage for one learner, oldest→newest.
    pub fn lineage(&self, learner_id: &str) -> &[StoredModel] {
        self.by_learner.get(learner_id).map(|v| v.as_slice()).unwrap_or(&[])
    }

    pub fn learner_count(&self) -> usize {
        self.by_learner.len()
    }
}

impl ModelStore for InMemoryStore {
    fn insert(&mut self, entry: StoredModel) -> Result<()> {
        self.by_learner.entry(entry.learner_id.clone()).or_default().push(entry);
        Ok(())
    }

    fn latest(&self, learner_id: &str) -> Result<Option<StoredModel>> {
        Ok(self
            .by_learner
            .get(learner_id)
            .and_then(|v| v.iter().max_by_key(|m| m.round))
            .cloned())
    }

    fn len(&self) -> usize {
        self.by_learner.values().map(|v| v.len()).sum()
    }

    fn byte_size(&self) -> usize {
        self.by_learner
            .values()
            .flat_map(|v| v.iter())
            .map(|m| m.model.byte_size_f32())
            .sum()
    }

    fn evict(&mut self, keep_last: usize) -> Result<usize> {
        let mut evicted = 0;
        for v in self.by_learner.values_mut() {
            v.sort_by_key(|m| m.round);
            while v.len() > keep_last {
                v.remove(0);
                evicted += 1;
            }
        }
        Ok(evicted)
    }

    fn name(&self) -> &'static str {
        "memory"
    }
}

#[cfg(test)]
mod tests {
    use super::super::test_support;
    use super::*;

    #[test]
    fn conformance() {
        let mut s = InMemoryStore::new();
        test_support::conformance(&mut s);
    }

    #[test]
    fn lineage_grows_and_evicts_in_round_order() {
        let mut s = InMemoryStore::new();
        for round in [3u64, 1, 2] {
            s.insert(test_support::entry("x", round, round)).unwrap();
        }
        assert_eq!(s.lineage("x").len(), 3);
        assert_eq!(s.latest("x").unwrap().unwrap().round, 3);
        s.evict(2).unwrap();
        let rounds: Vec<u64> = s.lineage("x").iter().map(|m| m.round).collect();
        assert_eq!(rounds, vec![2, 3]);
        assert_eq!(s.learner_count(), 1);
    }
}
