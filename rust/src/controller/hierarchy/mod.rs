//! Hierarchical aggregation tier: sharded aggregators between the root
//! controller and the fleet.
//!
//! The controller is the scalability bottleneck of a flat federation —
//! fan-out, quorum bookkeeping, and the delta-base map are all O(fleet)
//! in one process. An [`AggregatorNode`] interposes: it *embeds* a full
//! shard-local [`Controller`] (the same aggregate-on-arrival ingest,
//! round barrier, and streamed data plane the root runs), registers
//! with the root as a learner-like peer, and forwards **one partial
//! weighted sum + the shard's total weight** upstream per round. Root
//! ingest is O(aggregators) instead of O(learners), and dispatch
//! becomes a tree: the root encodes once for A aggregators, each
//! aggregator re-fans-out to its own shard.
//!
//! Because weighted FedAvg is associative — each shard folds its
//! arrivals in sorted-id order, the root folds shard partials in
//! sorted-id order, and every coefficient is `wᵢ/W` — the root
//! community model is **bitwise identical** to a flat controller
//! folding the same groups in the same order (see
//! [`two_tier_reference`], which is exactly that grouped fold).
//! Adaptive server rules (FedAdam & co.) keep their state at the root:
//! the shard env forces plain `fedavg`, so a partial is always the
//! associative weighted sum the root rule expects as one contribution.

use super::aggregation::{AggregationRule, Backend, Contribution, FedAvg};
use super::health::FailureDetector;
use super::Controller;
use crate::config::{FederationEnv, TopologySpec};
use crate::net::retry::RetryPolicy;
use crate::net::{ClientConn, Psk, Service};
use crate::obs::SpanCtx;
use crate::proto::client::{self, RpcError, StreamSend};
use crate::proto::ingest::{StreamBegin, StreamIngest};
use crate::proto::wire::{fnv1a64, FNV64_INIT};
use crate::proto::{
    ErrorCode, EvalResult, HealthProbe, Message, ModelProto, StreamPurpose, TaskMeta, TaskSpec,
    PROTO_VERSION,
};
use crate::tensor::{ByteOrder, CodecId, DType, TensorModel};
use crate::proto::ingest::IngestLimits;
use crate::util::{log_debug, log_info, log_warn, Rng, Stopwatch, ThreadPool};
use anyhow::{bail, Result};
use std::collections::HashSet;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Derive the shard-local environment an aggregator's embedded
/// controller runs: same model/protocol/data-plane settings as the
/// root, shard-sized fleet, the effective shard quorum, and — always —
/// plain `fedavg` (adaptive server optimizers keep their state at the
/// root; a shard must forward the associative weighted sum).
fn shard_env(env: &FederationEnv, id: &str, shard_size: usize) -> FederationEnv {
    let mut e = env.clone();
    e.name = format!("{}/{}", env.name, id);
    e.learners = shard_size.max(1);
    e.quorum_fraction = env.topology.effective_shard_quorum(env.quorum_fraction);
    e.aggregation.rule = "fedavg".into();
    e.topology = TopologySpec::default();
    e
}

/// An intermediate aggregator: shard-local controller + upstream
/// learner-like client, exposed to the network via
/// [`AggregatorServicer`].
pub struct AggregatorNode {
    pub id: String,
    upstream: String,
    psk: Psk,
    /// The embedded shard controller — aggregate-on-arrival ingest,
    /// round barrier, pacing, and the streamed data plane, unchanged.
    inner: Arc<Controller>,
    /// Ingest engine for *dispatch* streams arriving from the root
    /// (RunTask / Evaluate). Kept separate from the embedded
    /// controller's upload plane so a root dispatch never contends with
    /// a shard learner's completion stream.
    ingest: StreamIngest,
    /// Stream ids currently routed to `ingest` (root dispatch) rather
    /// than the embedded controller's upload plane. Ids are
    /// process-salted (see `client::next_stream_id`), so a shard
    /// learner's upload id practically never collides with a live
    /// dispatch id; entries are removed at `End` (or on chunk error).
    dispatch_streams: Mutex<HashSet<u64>>,
    /// Identity + pointer of the last losslessly dispatched model —
    /// the delta base for decoding the next delta-coded dispatch and
    /// for encoding the partial-sum upload (mirror of the learner's
    /// `last_community`).
    last_model: Mutex<Option<(u64, Arc<TensorModel>)>>,
    upstream_conn: Mutex<Option<Box<dyn ClientConn>>>,
    /// Codec set the root accepted in this connection's `Hello`.
    accepted_upstream: Mutex<Option<Vec<CodecId>>>,
    /// Single-threaded: shard rounds execute in dispatch order.
    executor: ThreadPool,
    /// Failure detector over this shard's learners, fed by the probe
    /// sweeps a root heartbeat cascades into ([`AggregatorNode::probe_shard`]).
    detector: FailureDetector,
    shutdown: AtomicBool,
    /// Partial uploads abandoned after retry exhaustion (this node's
    /// own upstream leg; the embedded controller counts its own).
    retry_give_ups: AtomicU64,
    /// Delta→f32 fallback re-sends on the upstream leg.
    fallback_sends: AtomicU64,
    /// Shard rounds whose partial sum reached the root.
    rounds_forwarded: AtomicU64,
}

impl AggregatorNode {
    pub fn new(
        id: &str,
        upstream: &str,
        env: &FederationEnv,
        shard_size: usize,
        psk: Psk,
    ) -> Result<Arc<AggregatorNode>> {
        let inner = Controller::new(shard_env(env, id, shard_size), psk)?;
        let clock = inner.clock().clone();
        log_info("aggregator", &format!("{id}: shard controller up (≤{shard_size} learners)"));
        Ok(Arc::new(AggregatorNode {
            id: id.to_string(),
            upstream: upstream.to_string(),
            psk,
            ingest: StreamIngest::with_clock(
                IngestLimits::default(),
                clock.clone(),
                Arc::clone(inner.counters()),
            ),
            inner,
            dispatch_streams: Mutex::new(HashSet::new()),
            last_model: Mutex::new(None),
            upstream_conn: Mutex::new(None),
            accepted_upstream: Mutex::new(None),
            detector: FailureDetector::new(env.health, clock.clone()),
            executor: ThreadPool::with_clock(1, clock),
            shutdown: AtomicBool::new(false),
            retry_give_ups: AtomicU64::new(0),
            fallback_sends: AtomicU64::new(0),
            rounds_forwarded: AtomicU64::new(0),
        }))
    }

    /// The embedded shard controller (registration barriers, counters,
    /// shard-local gauges).
    pub fn inner(&self) -> &Arc<Controller> {
        &self.inner
    }

    pub fn is_shutdown(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst)
    }

    /// Crash-stop this aggregator (chaos kill): every subsequent RPC —
    /// probes included — answers `Unavailable`, so the root's failure
    /// detector counts misses until it declares the node dead and the
    /// driver's failover path re-homes the shard. The embedded shard
    /// controller is shut down too, so queued shard rounds exit instead
    /// of dispatching from a dead node.
    pub fn kill(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
        let _ = self.inner.handle(Message::Shutdown);
    }

    /// This shard's failure detector (fed by [`AggregatorNode::probe_shard`]).
    pub fn detector(&self) -> &FailureDetector {
        &self.detector
    }

    /// Component state for heartbeat acks: the embedded controller's
    /// snapshot, plus this node's own dispatch ingest plane and its
    /// upstream give-ups.
    pub fn health_probe(&self) -> HealthProbe {
        let inner = self.inner.health_probe();
        HealthProbe {
            open_rounds: inner.open_rounds,
            open_streams: inner.open_streams + self.ingest.open_streams() as u64,
            retry_give_ups: self.retry_give_ups(),
        }
    }

    /// Probe every shard learner once (the aggregator→learner heartbeat
    /// leg), feeding this node's failure detector. Queued on the round
    /// executor by an incoming root heartbeat, so probing cascades down
    /// the tree: the driver probes the root tier, each aggregator
    /// probes its own shard.
    pub fn probe_shard(self: &Arc<Self>) {
        let node = Arc::clone(self);
        self.executor.spawn(move || {
            if node.is_shutdown() {
                return;
            }
            for h in node.inner.learners_snapshot() {
                let from = format!("aggregator/{}", node.id);
                let outcome = crate::net::connect(&h.endpoint, node.psk)
                    .map_err(RpcError::Transport)
                    .and_then(|mut conn| client::heartbeat_probe(conn.as_mut(), &from));
                match outcome {
                    Ok((_, healthy, _)) => node.detector.observe_ack(&h.id, healthy),
                    Err(_) => node.detector.observe_miss(&h.id),
                }
            }
        });
    }

    /// Give-ups across both leg directions: this node's upstream
    /// partial uploads plus the embedded controller's shard dispatches.
    pub fn retry_give_ups(&self) -> u64 {
        self.retry_give_ups.load(Ordering::SeqCst) + self.inner.retry_give_ups()
    }

    /// Delta→f32 fallbacks across both legs.
    pub fn fallback_sends(&self) -> u64 {
        self.fallback_sends.load(Ordering::SeqCst) + self.inner.fallback_sends()
    }

    /// Shard rounds whose partial sum reached the root.
    pub fn rounds_forwarded(&self) -> u64 {
        self.rounds_forwarded.load(Ordering::SeqCst)
    }

    /// Run `f` against the (lazily dialed) upstream connection — the
    /// same discipline as the learner's callback leg: a fresh
    /// connection opens with the versioned `Hello` handshake, transport
    /// failures drop it so the next call re-dials, remote application
    /// errors keep it.
    fn with_upstream_conn<T>(
        &self,
        f: impl FnOnce(&mut dyn ClientConn) -> Result<T, RpcError>,
    ) -> Result<T, RpcError> {
        let mut guard = self.upstream_conn.lock().unwrap();
        if guard.is_none() {
            let mut conn =
                crate::net::connect(&self.upstream, self.psk).map_err(RpcError::Transport)?;
            let (_, accepted) = client::hello_negotiate(conn.as_mut())?;
            *self.accepted_upstream.lock().unwrap() = Some(accepted);
            *guard = Some(conn);
        }
        match f(guard.as_mut().unwrap().as_mut()) {
            Ok(v) => Ok(v),
            Err(e) => {
                if e.is_transport() {
                    *guard = None; // force reconnect next time
                }
                Err(e)
            }
        }
    }

    /// Register with the root as a learner-like peer: the root's
    /// scheduler, quorum barrier, and pacing treat the whole shard as
    /// one participant weighted by its aggregate sample count.
    pub fn register(&self, own_endpoint: &str, shard_samples: usize) -> Result<usize> {
        self.with_upstream_conn(|conn| client::register(conn, &self.id, own_endpoint, shard_samples))
            .map_err(|e| anyhow::anyhow!("aggregator {}: upstream registration: {e}", self.id))
    }

    /// Graceful departure from the root.
    pub fn deregister(&self) -> Result<()> {
        self.with_upstream_conn(|conn| client::deregister(conn, &self.id))
            .map_err(|e| anyhow::anyhow!("aggregator {}: upstream deregistration: {e}", self.id))
    }

    /// Record a lossless dispatched model as the shared delta base.
    fn record_model(&self, round: u64, codec: CodecId, model: &Arc<TensorModel>) {
        if codec.is_lossless() {
            *self.last_model.lock().unwrap() = Some((round, Arc::clone(model)));
        }
    }

    /// Queue a shard round on the single-threaded executor (rounds run
    /// in dispatch order, like the learner's training executor).
    fn queue_shard_round(
        self: &Arc<Self>,
        task_id: u64,
        model_round: u64,
        model: Arc<TensorModel>,
        spec: TaskSpec,
        ctx: SpanCtx,
    ) {
        let node = Arc::clone(self);
        self.executor.spawn(move || {
            if node.is_shutdown() {
                return;
            }
            if let Err(e) = node.run_shard_round(task_id, model_round, model, spec, ctx) {
                log_warn("aggregator", &format!("{}: round {task_id} failed: {e:#}", node.id));
            }
        });
    }

    /// One shard round: install the dispatched model as the shard's
    /// community model, re-fan-out to the shard, run the shard barrier,
    /// fold the arrivals (sorted-id order — the flat fold order), and
    /// forward the partial sum + total weight upstream.
    fn run_shard_round(
        &self,
        task_id: u64,
        model_round: u64,
        model: Arc<TensorModel>,
        spec: TaskSpec,
        ctx: SpanCtx,
    ) -> Result<()> {
        let started = Stopwatch::start_with(self.inner.clock());
        // Parent the whole shard round under the root's dispatch span
        // (`ctx` rode the dispatch stream's meta), and hand the shard
        // span to the embedded controller so its own fan-out /
        // aggregation spans nest under it — one trace, two tiers.
        let shard_span = self
            .inner
            .span_sink()
            .begin("shard_round", ctx)
            .peer(&self.id)
            .round(model_round)
            .task(task_id);
        self.inner.set_span_parent(shard_span.ctx());
        // The dispatched model becomes the shard's community model at
        // the dispatched round, so the shard-local data plane (delta
        // bases, fold input) matches what a flat controller holds.
        {
            let mut s = self.inner.state.lock().unwrap();
            s.community = Some(Arc::clone(&model));
            s.community_round = model_round;
        }
        let targets = self.inner.learners_snapshot();
        if targets.is_empty() {
            bail!("shard {} has no registered learners", self.id);
        }
        let ids: Vec<String> = targets.iter().map(|h| h.id.clone()).collect();
        self.inner.open_round(task_id, &ids);
        let streamed = self.inner.env.stream_chunk_bytes > 0;
        let (_dispatch, replies) = if streamed {
            self.inner.stream_broadcast(
                &targets,
                StreamPurpose::RunTask,
                task_id,
                &spec,
                None,
                &model,
                model_round,
            )
        } else {
            let proto = ModelProto::from_model(&model, DType::F32, ByteOrder::Little);
            let msg = Message::RunTask { task_id, round: model_round, model: proto, spec };
            self.inner.broadcast(&targets, &msg)
        };
        let mut delivered = 0usize;
        for (lid, r) in &replies {
            match r {
                Ok(m) if !matches!(m, Message::Error { .. }) => delivered += 1,
                Ok(m) => log_warn(
                    "aggregator",
                    &format!("{}: dispatch to {lid} refused: {}", self.id, m.kind()),
                ),
                Err(e) => {
                    log_warn("aggregator", &format!("{}: dispatch to {lid} failed: {e:#}", self.id))
                }
            }
        }
        if delivered == 0 {
            // Nothing can arrive: close the barrier so the next round
            // starts clean, then surface the failure (the root sees a
            // missing shard, exactly like a failed learner).
            let _ = self.inner.wait_round_quorum(Duration::ZERO, 1.0);
            bail!("shard {}: no learner accepted round {task_id}", self.id);
        }
        let timeout = Duration::from_millis(self.inner.env.task_timeout_ms);
        let outcome = self.inner.wait_round_quorum(timeout, self.inner.env.quorum_fraction);
        for id in &outcome.missing {
            self.inner.pacing().observe_failure(id);
        }
        if outcome.arrived.is_empty() {
            bail!("shard {}: round {task_id} closed with no completions", self.id);
        }
        // The shard's total weight — read before the fold, which evicts
        // the stored contributions.
        let weight: usize = {
            let s = self.inner.state.lock().unwrap();
            s.store
                .select_latest(&outcome.arrived)?
                .iter()
                .map(|m| m.meta.num_samples.max(1))
                .sum()
        };
        let partial = self.inner.aggregate_from_store(&outcome.arrived, task_id)?;
        self.upload_partial(
            task_id,
            model_round,
            &partial,
            weight,
            started.elapsed(),
            shard_span.ctx(),
        )?;
        self.rounds_forwarded.fetch_add(1, Ordering::SeqCst);
        log_debug(
            "aggregator",
            &format!(
                "{}: round {task_id} folded {}/{} learners (weight {weight}) and forwarded",
                self.id,
                outcome.arrived.len(),
                ids.len()
            ),
        );
        Ok(())
    }

    /// Forward the shard's partial weighted sum + total weight to the
    /// root: a `PartialAggregate` stream over the same codec-negotiated
    /// chunked data plane learners upload on (one-shot
    /// `MarkTaskCompleted` when the env doesn't stream). The shard
    /// weight rides `TaskMeta::num_samples`, so the root's FedAvg
    /// reweighting over partials needs no new wire state.
    fn upload_partial(
        &self,
        task_id: u64,
        model_round: u64,
        partial: &Arc<TensorModel>,
        weight: usize,
        elapsed: Duration,
        ctx: SpanCtx,
    ) -> Result<()> {
        let upload_span = self
            .inner
            .span_sink()
            .begin("partial_upload", ctx)
            .peer(&self.id)
            .round(model_round)
            .task(task_id);
        // The upload span's context rides the meta, so the ROOT's
        // ingest span parents under this hop.
        let meta = TaskMeta {
            num_samples: weight,
            train_wall_time_us: (elapsed.as_micros() as u64).max(1),
            ..TaskMeta::default()
        }
        .with_span_ctx(upload_span.ctx());
        let chunk = self.inner.env.stream_chunk_bytes;
        let policy = RetryPolicy::rpc();
        let mut rng = Rng::new(fnv1a64(FNV64_INIT, self.id.as_bytes()) ^ task_id);
        let fallback = self.inner.env.delta_fallback;
        let upload = if chunk > 0 {
            policy.run(
                self.inner.clock(),
                &mut rng,
                |_| {
                    // Ensure the upstream session (and its codec
                    // negotiation) exists before choosing a codec — a
                    // re-dial renegotiates.
                    self.with_upstream_conn(|_| Ok(()))?;
                    let configured = self.inner.env.upload_codec();
                    let configured = match self.accepted_upstream.lock().unwrap().as_ref() {
                        Some(accepted) => configured.degrade_to(accepted),
                        None => configured,
                    };
                    let (codec, base, base_round) = if configured.needs_base() {
                        match self.last_model.lock().unwrap().clone() {
                            // The root installed the same base when its
                            // lossless dispatch stream was acked.
                            Some((r, m)) => (configured, Some(m), r),
                            None => (CodecId::F32, None, 0),
                        }
                    } else {
                        (configured, None, 0)
                    };
                    let task_spec = TaskSpec::default();
                    let send = StreamSend {
                        purpose: StreamPurpose::PartialAggregate,
                        task_id,
                        round: model_round,
                        learner_id: &self.id,
                        model: partial,
                        meta: &meta,
                        spec: &task_spec,
                        codec,
                        base: base.as_deref(),
                        base_round,
                        chunk_bytes: chunk.max(client::MIN_CHUNK_BYTES),
                    };
                    self.with_upstream_conn(|conn| {
                        let rpc_fn = &mut |msg| client::rpc(&mut *conn, &msg);
                        if fallback {
                            client::stream_model_with_fallback_counted(rpc_fn, &send)
                                .map(|(_, fell_back)| fell_back)
                        } else {
                            client::stream_model_with(rpc_fn, &send).map(|_| false)
                        }
                    })
                },
                |e| e.is_transport(),
            )
        } else {
            policy.run(
                self.inner.clock(),
                &mut rng,
                |_| {
                    let proto = ModelProto::from_model(partial, DType::F32, ByteOrder::Little);
                    self.with_upstream_conn(|conn| {
                        client::mark_task_completed(conn, task_id, &self.id, proto, meta.clone())
                    })
                    .map(|()| false)
                },
                |e| e.is_transport(),
            )
        };
        match upload {
            Ok(fell_back) => {
                if fell_back {
                    self.fallback_sends.fetch_add(1, Ordering::SeqCst);
                }
                Ok(())
            }
            Err(give_up) => {
                if give_up.exhausted {
                    self.retry_give_ups.fetch_add(1, Ordering::SeqCst);
                }
                bail!(
                    "partial upload: gave up after {} attempts in {:?}: {}",
                    give_up.attempts,
                    give_up.elapsed,
                    give_up.last_error
                )
            }
        }
    }

    /// Evaluate on the shard and combine: sample-weighted mean loss,
    /// summed samples, slowest shard member's eval time (tree depth
    /// adds latency, not work).
    fn eval_on_shard(
        &self,
        task_id: u64,
        round: u64,
        model: &Arc<TensorModel>,
        ctx: SpanCtx,
    ) -> Message {
        let eval_span = self
            .inner
            .span_sink()
            .begin("shard_eval", ctx)
            .peer(&self.id)
            .round(round)
            .task(task_id);
        self.inner.set_span_parent(eval_span.ctx());
        let targets = self.inner.learners_snapshot();
        if targets.is_empty() {
            return Message::error(
                ErrorCode::Unavailable,
                format!("shard {} has no learners to evaluate on", self.id),
            );
        }
        let streamed = self.inner.env.stream_chunk_bytes > 0;
        let (_d, replies) = if streamed {
            self.inner.stream_broadcast(
                &targets,
                StreamPurpose::Evaluate,
                task_id,
                &TaskSpec::default(),
                None,
                model,
                round,
            )
        } else {
            let proto = ModelProto::from_model(model, DType::F32, ByteOrder::Little);
            self.inner.broadcast(&targets, &Message::EvaluateModel { task_id, round, model: proto })
        };
        let mut weighted = 0.0f64;
        let mut samples = 0usize;
        let mut max_t = 0u64;
        for (lid, r) in &replies {
            match r {
                Ok(Message::EvaluateModelReply { result, .. }) => {
                    weighted += result.loss * result.num_samples as f64;
                    samples += result.num_samples;
                    max_t = max_t.max(result.eval_time_us);
                }
                Ok(other) => log_warn(
                    "aggregator",
                    &format!("{}: eval on {lid}: unexpected {}", self.id, other.kind()),
                ),
                Err(e) => {
                    log_warn("aggregator", &format!("{}: eval on {lid} failed: {e:#}", self.id))
                }
            }
        }
        if samples == 0 {
            return Message::error(
                ErrorCode::Internal,
                format!("shard {}: no evaluation completed", self.id),
            );
        }
        Message::EvaluateModelReply {
            task_id,
            learner_id: self.id.clone(),
            result: EvalResult {
                loss: weighted / samples as f64,
                num_samples: samples,
                eval_time_us: max_t,
            },
        }
    }
}

/// The aggregator's [`Service`] facade. Shard membership and learner
/// completions route straight to the embedded controller (an
/// aggregator IS its shard's controller); root-originated dispatch
/// decodes on the node's own ingest and re-fans-out.
pub struct AggregatorServicer(pub Arc<AggregatorNode>);

impl Service for AggregatorServicer {
    fn handle(&self, msg: Message) -> Message {
        let node = &self.0;
        if node.is_shutdown() {
            return Message::error(ErrorCode::Unavailable, "aggregator is shut down");
        }
        match msg {
            Message::Hello { proto_version, codecs } => {
                if proto_version == PROTO_VERSION {
                    Message::HelloAck {
                        proto_version: PROTO_VERSION,
                        component: format!("aggregator/{}", node.id),
                        codecs: crate::tensor::codec::negotiate(&codecs, &client::SUPPORTED_CODECS),
                    }
                } else {
                    Message::error(
                        ErrorCode::VersionMismatch,
                        format!("aggregator speaks v{PROTO_VERSION}, peer v{proto_version}"),
                    )
                }
            }
            // Shard membership, learner completions, and model reads go
            // straight to the embedded shard controller.
            msg @ (Message::Register { .. }
            | Message::Deregister { .. }
            | Message::MarkTaskCompleted { .. }
            | Message::ShipModel { .. }
            | Message::GetModel) => node.inner.handle(msg),
            Message::Heartbeat { .. } => {
                // Sweep idle streams on BOTH planes (root dispatch and
                // shard uploads), like the flat components do — then
                // cascade: a root probe triggers this node's own probe
                // sweep of its shard learners (on the round executor).
                node.ingest.gc_idle();
                node.inner.ingest().gc_idle();
                node.probe_shard();
                let health = node.health_probe();
                Message::HeartbeatAck {
                    component: format!("aggregator/{}", node.id),
                    healthy: health.is_healthy(),
                    health,
                }
            }
            Message::Shutdown => {
                node.shutdown.store(true, Ordering::SeqCst);
                node.inner.handle(Message::Shutdown)
            }
            // One-shot dispatch carries no meta, hence no trace context.
            Message::RunTask { task_id, round, model, spec } => match model.to_model() {
                Ok(m) => {
                    node.queue_shard_round(task_id, round, Arc::new(m), spec, SpanCtx::UNSET);
                    Message::Ack { task_id, ok: true }
                }
                Err(e) => Message::error(ErrorCode::InvalidModel, format!("bad model: {e:#}")),
            },
            Message::EvaluateModel { task_id, round, model } => match model.to_model() {
                Ok(m) => node.eval_on_shard(task_id, round, &Arc::new(m), SpanCtx::UNSET),
                Err(e) => Message::error(ErrorCode::InvalidModel, format!("bad model: {e:#}")),
            },
            Message::ModelStreamBegin {
                stream_id,
                task_id,
                round,
                purpose,
                learner_id,
                codec,
                base_round,
                layout,
                meta,
                spec,
            } => {
                if matches!(purpose, StreamPurpose::RunTask | StreamPurpose::Evaluate) {
                    // Dispatch stream from the root: decode on the
                    // node's own ingest, not the shard upload plane.
                    let base = if codec.needs_base() {
                        node.last_model
                            .lock()
                            .unwrap()
                            .clone()
                            .filter(|(r, _)| *r == base_round)
                            .map(|(_, m)| m)
                    } else {
                        None
                    };
                    let reply = node.ingest.begin(
                        StreamBegin {
                            stream_id,
                            task_id,
                            round,
                            purpose,
                            learner_id,
                            codec,
                            base_round,
                            layout,
                            meta,
                            spec,
                        },
                        None,
                        base,
                    );
                    if !matches!(reply, Message::Error { .. }) {
                        node.dispatch_streams.lock().unwrap().insert(stream_id);
                    }
                    reply
                } else {
                    // Upload stream from a shard learner.
                    node.inner.handle(Message::ModelStreamBegin {
                        stream_id,
                        task_id,
                        round,
                        purpose,
                        learner_id,
                        codec,
                        base_round,
                        layout,
                        meta,
                        spec,
                    })
                }
            }
            Message::ModelChunk { stream_id, seq, bytes } => {
                if node.dispatch_streams.lock().unwrap().contains(&stream_id) {
                    let reply = node.ingest.chunk(stream_id, seq, bytes);
                    if matches!(reply, Message::Error { .. }) {
                        node.dispatch_streams.lock().unwrap().remove(&stream_id);
                    }
                    reply
                } else {
                    node.inner.handle(Message::ModelChunk { stream_id, seq, bytes })
                }
            }
            Message::ModelStreamEnd { stream_id, digest } => {
                if node.dispatch_streams.lock().unwrap().remove(&stream_id) {
                    let finished = match node.ingest.end(stream_id, digest) {
                        Ok(f) => f,
                        Err(reply) => return reply,
                    };
                    let model = Arc::new(finished.model);
                    let ctx = finished.meta.span_ctx();
                    match finished.purpose {
                        StreamPurpose::RunTask => {
                            node.record_model(finished.round, finished.codec, &model);
                            node.queue_shard_round(
                                finished.task_id,
                                finished.round,
                                model,
                                finished.spec,
                                ctx,
                            );
                            Message::Ack { task_id: finished.task_id, ok: true }
                        }
                        StreamPurpose::Evaluate => {
                            // The End reply IS the combined shard eval
                            // reply. Record the base only on success,
                            // matching the learner's discipline.
                            let reply = node.eval_on_shard(
                                finished.task_id,
                                finished.round,
                                &model,
                                ctx,
                            );
                            if !matches!(reply, Message::Error { .. }) {
                                node.record_model(finished.round, finished.codec, &model);
                            }
                            reply
                        }
                        _ => Message::error(ErrorCode::Unsupported, "unexpected upload stream"),
                    }
                } else {
                    node.inner.handle(Message::ModelStreamEnd { stream_id, digest })
                }
            }
            other => {
                Message::error(ErrorCode::Unsupported, format!("unexpected {}", other.kind()))
            }
        }
    }
}

/// Deterministic failover re-homing plan: orphan learner `i` (in the
/// dead shard's sorted order) joins surviving aggregator
/// `assignments[i]` (an index into the sorted survivor list),
/// round-robin so re-homed load spreads evenly. Shared by the driver's
/// failover path and the tests that reconstruct the post-failover
/// grouping for the bitwise reference fold — both sides MUST derive
/// the same plan.
pub fn rehome_assignments(orphans: usize, survivors: usize) -> Vec<usize> {
    assert!(survivors > 0, "failover needs at least one surviving aggregator");
    (0..orphans).map(|i| i % survivors).collect()
}

/// Reference two-tier fold: FedAvg each shard's contributions (sorted
/// the way the shard barrier sorts arrivals), then FedAvg the partials
/// in shard order with each shard's summed weight. This IS the flat
/// fold regrouped associatively — `current` is passed through for rule
/// parity but plain FedAvg ignores it. Empty shards are skipped (a
/// severed aggregator degrades the root to the surviving shards).
pub fn two_tier_reference(
    current: &TensorModel,
    shards: &[Vec<Contribution>],
    backend: &Backend,
) -> Result<TensorModel> {
    let mut rule = FedAvg::new();
    let mut partials: Vec<Contribution> = Vec::new();
    for shard in shards {
        if shard.is_empty() {
            continue;
        }
        let weight: f64 = shard.iter().map(|c| c.weight).sum();
        let folded = rule.aggregate(current, shard, backend)?;
        partials.push(Contribution { model: Arc::new(folded), weight });
    }
    if partials.is_empty() {
        bail!("two_tier_reference: every shard is empty");
    }
    rule.aggregate(current, &partials, backend)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{AggregationBackend, AggregationSpec, ModelSpec, TransportKind};
    use crate::net::chaos::ChaosPlan;
    use crate::proto::ingest::StreamIngest;
    use std::sync::Mutex as StdMutex;

    fn digest(m: &TensorModel) -> u64 {
        let mut h = FNV64_INIT;
        for t in &m.tensors {
            for v in &t.data {
                h = fnv1a64(h, &v.to_bits().to_le_bytes());
            }
        }
        h
    }

    fn test_env(name: &str, learners: usize) -> FederationEnv {
        FederationEnv::builder(name)
            .learners(learners)
            .rounds(1)
            .model(ModelSpec::mlp(4, 2, 8))
            .aggregation(AggregationSpec {
                backend: AggregationBackend::Sequential,
                ..AggregationSpec::default()
            })
            .transport(TransportKind::InProc)
            .samples_per_learner(10)
            .seed(7)
            .task_timeout_ms(10_000)
            .build()
    }

    fn layout_model(seed: u64) -> TensorModel {
        let layout = ModelSpec::mlp(4, 2, 8).tensor_layout();
        TensorModel::random_init(&layout, &mut Rng::new(seed))
    }

    /// Learner stub: any RunTask dispatch (one-shot or streamed) makes
    /// it call `MarkTaskCompleted` back to its aggregator with a fixed
    /// deterministic update and weight, synchronously, then ack.
    struct StubLearner {
        id: String,
        weight: usize,
        callback: String,
        update: TensorModel,
        ingest: StreamIngest,
        uploads: StdMutex<u64>,
    }

    impl StubLearner {
        fn new(id: &str, weight: usize, callback: &str, seed: u64) -> StubLearner {
            StubLearner {
                id: id.to_string(),
                weight,
                callback: callback.to_string(),
                update: layout_model(seed),
                ingest: StreamIngest::default(),
                uploads: StdMutex::new(0),
            }
        }

        fn contribution(&self) -> Contribution {
            Contribution { model: Arc::new(self.update.clone()), weight: self.weight as f64 }
        }

        fn upload(&self, task_id: u64) {
            let mut conn = crate::net::connect(&self.callback, None).unwrap();
            client::hello_negotiate(conn.as_mut()).unwrap();
            let proto = ModelProto::from_model(&self.update, DType::F32, ByteOrder::Little);
            let meta = TaskMeta {
                num_samples: self.weight,
                completed_steps: 1,
                train_wall_time_us: 1_000,
                ..TaskMeta::default()
            };
            client::mark_task_completed(conn.as_mut(), task_id, &self.id, proto, meta).unwrap();
            *self.uploads.lock().unwrap() += 1;
        }
    }

    impl Service for StubLearner {
        fn handle(&self, msg: Message) -> Message {
            match msg {
                Message::Hello { .. } => Message::HelloAck {
                    proto_version: PROTO_VERSION,
                    component: format!("learner/{}", self.id),
                    codecs: client::SUPPORTED_CODECS.to_vec(),
                },
                Message::RunTask { task_id, .. } => {
                    self.upload(task_id);
                    Message::Ack { task_id, ok: true }
                }
                Message::ModelStreamBegin {
                    stream_id,
                    task_id,
                    round,
                    purpose,
                    learner_id,
                    codec,
                    base_round,
                    layout,
                    meta,
                    spec,
                } => self.ingest.begin(
                    StreamBegin {
                        stream_id,
                        task_id,
                        round,
                        purpose,
                        learner_id,
                        codec,
                        base_round,
                        layout,
                        meta,
                        spec,
                    },
                    None,
                    None,
                ),
                Message::ModelChunk { stream_id, seq, bytes } => {
                    self.ingest.chunk(stream_id, seq, bytes)
                }
                Message::ModelStreamEnd { stream_id, digest } => {
                    match self.ingest.end(stream_id, digest) {
                        Ok(f) => match f.purpose {
                            StreamPurpose::RunTask => {
                                self.upload(f.task_id);
                                Message::Ack { task_id: f.task_id, ok: true }
                            }
                            StreamPurpose::Evaluate => Message::EvaluateModelReply {
                                task_id: f.task_id,
                                learner_id: self.id.clone(),
                                result: EvalResult {
                                    loss: 0.5,
                                    num_samples: self.weight,
                                    eval_time_us: 10,
                                },
                            },
                            _ => Message::error(ErrorCode::Unsupported, "unexpected purpose"),
                        },
                        Err(reply) => reply,
                    }
                }
                Message::Heartbeat { .. } => Message::HeartbeatAck {
                    component: self.id.clone(),
                    healthy: true,
                    health: HealthProbe::default(),
                },
                Message::Shutdown => Message::Ack { task_id: 0, ok: true },
                other => {
                    Message::error(ErrorCode::Unsupported, format!("unexpected {}", other.kind()))
                }
            }
        }
    }

    /// Satellite: `Deregister` of a mid-round learner behind an
    /// aggregator — the shard barrier re-targets, the partial sum
    /// excludes the departed learner, and the root community model is
    /// bitwise equal to the direct fold over the survivors.
    #[test]
    fn deregister_behind_aggregator_retargets_and_stays_bitwise() {
        let env = test_env("h-dereg", 3);
        let root = Controller::new(env.clone(), None).unwrap();
        let _root_srv =
            crate::net::serve("inproc://h-dereg-root", root.clone() as Arc<dyn Service>, None)
                .unwrap();
        let initial = layout_model(42);
        root.ship_model(initial.clone());

        let node = AggregatorNode::new("agg-0", "inproc://h-dereg-root", &env, 3, None).unwrap();
        let svc = Arc::new(AggregatorServicer(Arc::clone(&node)));
        let _agg_srv =
            crate::net::serve("inproc://h-dereg-agg0", svc.clone() as Arc<dyn Service>, None)
                .unwrap();

        let la = Arc::new(StubLearner::new("l-a", 3, "inproc://h-dereg-agg0", 101));
        let lb = Arc::new(StubLearner::new("l-b", 5, "inproc://h-dereg-agg0", 102));
        let _sa =
            crate::net::serve("inproc://h-dereg-la", la.clone() as Arc<dyn Service>, None).unwrap();
        let _sb =
            crate::net::serve("inproc://h-dereg-lb", lb.clone() as Arc<dyn Service>, None).unwrap();
        node.inner().register_learner("l-a", "inproc://h-dereg-la", 3);
        node.inner().register_learner("l-b", "inproc://h-dereg-lb", 5);
        // A third shard member whose endpoint is never served: its
        // dispatch fails, and under full quorum the shard barrier would
        // hold until the task timeout — unless it deregisters.
        node.inner().register_learner("l-ghost", "inproc://h-dereg-ghost", 7);
        node.register("inproc://h-dereg-agg0", 15).unwrap();

        root.open_round(1, &["agg-0".to_string()]);
        let proto = ModelProto::from_model(&initial, DType::F32, ByteOrder::Little);
        let reply = svc.handle(Message::RunTask {
            task_id: 1,
            round: 0,
            model: proto,
            spec: TaskSpec::default(),
        });
        assert!(matches!(reply, Message::Ack { ok: true, .. }), "dispatch refused: {reply:?}");

        // Let the live learners complete, then pull the ghost out
        // mid-round: the barrier must re-target and close.
        while *la.uploads.lock().unwrap() == 0 || *lb.uploads.lock().unwrap() == 0 {
            crate::util::Clock::system().sleep(Duration::from_millis(10));
        }
        let reply = svc.handle(Message::Deregister { learner_id: "l-ghost".to_string() });
        assert!(matches!(reply, Message::Ack { ok: true, .. }), "deregister failed: {reply:?}");

        let outcome = root.wait_round_quorum(Duration::from_secs(10), 1.0);
        assert_eq!(outcome.arrived, vec!["agg-0".to_string()]);
        // The stored partial's weight excludes the departed learner.
        {
            let s = root.state.lock().unwrap();
            let stored = s.store.select_latest(&["agg-0".to_string()]).unwrap();
            assert_eq!(stored.len(), 1);
            assert_eq!(stored[0].meta.num_samples, 8, "3 + 5, ghost's 7 excluded");
        }
        let community = root.aggregate_from_store(&["agg-0".to_string()], 1).unwrap();

        // Direct fold over the survivors, in the shard's sorted-id
        // order, through the same backend.
        let backend = Backend::Sequential;
        let expected =
            two_tier_reference(&initial, &[vec![la.contribution(), lb.contribution()]], &backend)
                .unwrap();
        assert_eq!(digest(&community), digest(&expected), "tiered fold diverged from direct fold");
    }

    /// Satellite: sever an aggregator via the dispatch-direction chaos
    /// plan — the root's streamed fan-out gives up on the dead shard,
    /// the quorum barrier closes on the survivors, and the community
    /// model equals the reference fold over the surviving shards only.
    #[test]
    fn severed_aggregator_degrades_root_to_surviving_shards() {
        let mut env = test_env("h-sever", 2);
        env.quorum_fraction = 0.5;
        env.stream_chunk_bytes = 2048;
        let root = Controller::new(env.clone(), None).unwrap();
        let _root_srv =
            crate::net::serve("inproc://h-sever-root", root.clone() as Arc<dyn Service>, None)
                .unwrap();
        let initial = layout_model(43);
        root.ship_model(initial.clone());

        let mut nodes = Vec::new();
        let mut stubs = Vec::new();
        for i in 0..2 {
            let node = AggregatorNode::new(
                &format!("agg-{i}"),
                "inproc://h-sever-root",
                &env,
                1,
                None,
            )
            .unwrap();
            let svc = Arc::new(AggregatorServicer(Arc::clone(&node)));
            let ep = format!("inproc://h-sever-agg{i}");
            let _srv = crate::net::serve(&ep, svc as Arc<dyn Service>, None).unwrap();
            let stub = Arc::new(StubLearner::new(&format!("l-{i}"), 4, &ep, 200 + i as u64));
            let lep = format!("inproc://h-sever-l{i}");
            let _lsrv = crate::net::serve(&lep, stub.clone() as Arc<dyn Service>, None).unwrap();
            node.inner().register_learner(&format!("l-{i}"), &lep, 4);
            node.register(&ep, 4).unwrap();
            nodes.push((node, _srv));
            stubs.push((stub, _lsrv));
        }

        // Kill the root→agg-1 link before the round: every dial routes
        // through a transport that dies on the first send.
        let mut sever = ChaosPlan::default();
        sever.sever_after_sends = Some(0);
        assert!(root.set_dispatch_chaos("agg-1", sever));
        assert!(!root.set_dispatch_chaos("nobody", ChaosPlan::default()));

        let targets = root.learners_snapshot();
        let ids: Vec<String> = targets.iter().map(|h| h.id.clone()).collect();
        root.open_round(1, &ids);
        let model = Arc::new(initial.clone());
        let (_d, _replies) = root.stream_broadcast(
            &targets,
            StreamPurpose::RunTask,
            1,
            &TaskSpec::default(),
            None,
            &model,
            0,
        );
        let outcome = root.wait_round_quorum(Duration::from_secs(10), env.quorum_fraction);
        assert_eq!(outcome.arrived, vec!["agg-0".to_string()]);
        assert_eq!(outcome.missing, vec!["agg-1".to_string()]);
        assert!(root.retry_give_ups() > 0, "severed dispatch must surface as a give-up");

        let community = root.aggregate_from_store(&outcome.arrived, 1).unwrap();
        let backend = Backend::Sequential;
        let expected = two_tier_reference(
            &initial,
            &[vec![stubs[0].0.contribution()], Vec::new()],
            &backend,
        )
        .unwrap();
        assert_eq!(
            digest(&community),
            digest(&expected),
            "root must degrade to the surviving shard, bitwise"
        );
    }

    /// Satellite: a peer that only speaks the pre-v5 codec set (f32 +
    /// delta) negotiates the auto/delta-rle dispatch down to delta on
    /// both directions instead of refusing at `Begin`.
    #[test]
    fn delta_only_peer_negotiates_down() {
        struct LegacyPeer;
        impl Service for LegacyPeer {
            fn handle(&self, msg: Message) -> Message {
                match msg {
                    Message::Hello { proto_version, codecs } => Message::HelloAck {
                        proto_version,
                        component: "legacy".into(),
                        codecs: codecs
                            .into_iter()
                            .filter(|c| matches!(c, CodecId::F32 | CodecId::Delta))
                            .collect(),
                    },
                    other => Message::error(
                        ErrorCode::Unsupported,
                        format!("unexpected {}", other.kind()),
                    ),
                }
            }
        }
        let mut env = test_env("h-compat", 1);
        env.stream_chunk_bytes = 2048;
        assert_eq!(env.dispatch_codec(), CodecId::DeltaRle, "auto must prefer delta-rle");
        let root = Controller::new(env, None).unwrap();
        let _srv =
            crate::net::serve("inproc://h-compat-peer", Arc::new(LegacyPeer), None).unwrap();
        root.register_learner("legacy", "inproc://h-compat-peer", 1);
        let negotiated = root.negotiate_dispatch_codec(&root.learners_snapshot());
        assert_eq!(negotiated, CodecId::Delta, "dispatch must degrade delta-rle → delta");
        // Upload direction: the same accepted set degrades the
        // configured upload codec along the lossless chain.
        assert_eq!(CodecId::DeltaRle.degrade_to(&[CodecId::F32, CodecId::Delta]), CodecId::Delta);
    }

    /// Tentpole: a root heartbeat makes the aggregator (a) report real
    /// component state instead of a hardcoded `healthy: true`, and (b)
    /// cascade a probe sweep over its own shard, feeding its failure
    /// detector — a served learner stays Alive while a ghost endpoint
    /// decays to Dead.
    #[test]
    fn aggregator_heartbeat_reports_state_and_cascades_probes() {
        use super::super::health::PeerStatus;
        let env = test_env("h-health", 2);
        let node =
            AggregatorNode::new("agg-h", "inproc://h-health-root-unused", &env, 2, None).unwrap();
        let svc = AggregatorServicer(Arc::clone(&node));
        let live = Arc::new(StubLearner::new("l-live", 4, "inproc://h-health-cb-unused", 300));
        let _lsrv =
            crate::net::serve("inproc://h-health-live", live as Arc<dyn Service>, None).unwrap();
        node.inner().register_learner("l-live", "inproc://h-health-live", 4);
        node.inner().register_learner("l-ghost", "inproc://h-health-ghost", 4);

        // Each heartbeat queues one probe sweep; with dead_after 5 the
        // ghost must be declared dead within a handful of sweeps.
        let sw = Stopwatch::start();
        loop {
            match svc.handle(Message::Heartbeat { from: "root".into() }) {
                Message::HeartbeatAck { component, healthy, health } => {
                    assert_eq!(component, "aggregator/agg-h");
                    assert!(healthy, "fresh aggregator must ack healthy");
                    assert_eq!(health.retry_give_ups, 0);
                }
                other => panic!("unexpected {}", other.kind()),
            }
            if node.detector().status("l-ghost") == PeerStatus::Dead {
                break;
            }
            assert!(sw.elapsed() < Duration::from_secs(10), "ghost never declared dead");
            crate::util::Clock::system().sleep(Duration::from_millis(5));
        }
        assert_eq!(node.detector().status("l-live"), PeerStatus::Alive);

        // An upstream give-up degrades the ack.
        node.retry_give_ups.fetch_add(1, Ordering::SeqCst);
        match svc.handle(Message::Heartbeat { from: "root".into() }) {
            Message::HeartbeatAck { healthy, health, .. } => {
                assert!(!healthy, "give-ups must degrade the ack");
                assert_eq!(health.retry_give_ups, 1);
            }
            other => panic!("unexpected {}", other.kind()),
        }

        // kill(): a crash-stopped node refuses everything, probes
        // included — that is the miss signal failover keys off.
        node.kill();
        assert!(matches!(
            svc.handle(Message::Heartbeat { from: "root".into() }),
            Message::Error { code: ErrorCode::Unavailable, .. }
        ));
    }

    /// The re-homing plan is deterministic round-robin and panics
    /// without survivors (failover is impossible then by construction —
    /// env validation refuses single-aggregator kill plans).
    #[test]
    fn rehome_assignments_round_robin() {
        assert_eq!(rehome_assignments(0, 3), Vec::<usize>::new());
        assert_eq!(rehome_assignments(4, 2), vec![0, 1, 0, 1]);
        assert_eq!(rehome_assignments(3, 5), vec![0, 1, 2]);
        assert!(std::panic::catch_unwind(|| rehome_assignments(1, 0)).is_err());
    }

    /// The reference fold with one shard of one contribution is the
    /// identity (coefficient 1.0 is exact), and shard grouping
    /// preserves total weight through the root fold.
    #[test]
    fn two_tier_reference_single_contribution_is_identity() {
        let current = layout_model(7);
        let update = layout_model(8);
        let backend = Backend::Sequential;
        let c = Contribution { model: Arc::new(update.clone()), weight: 5.0 };
        let folded = two_tier_reference(&current, &[vec![c]], &backend).unwrap();
        assert_eq!(digest(&folded), digest(&update));
        assert!(two_tier_reference(&current, &[Vec::new()], &backend).is_err());
    }
}
