//! Learner pacing subsystem: per-learner performance profiles driving
//! straggler-aware scheduling.
//!
//! The paper makes task dispatching and scheduling a first-class
//! controller responsibility, and the semi-synchronous protocol it
//! cites (Stripelis, Thompson & Ambite, 2022b) derives *per-learner*
//! step budgets from measured throughput so heterogeneous fleets finish
//! a round at the same wall clock. This module is the measurement half:
//! a [`PacingRegistry`] accumulates, per learner id, an EWMA of
//! steps-per-second (from the completion telemetry carried by
//! `TaskMeta`), an EWMA of task round-trip time, and a
//! completion/failure history.
//!
//! Three consumers:
//!
//! * **True semi-sync** — [`PacingRegistry::step_budgets`] computes
//!   `budget_i = t_target · throughput_i` (with `t_target` anchored so
//!   the slowest profiled learner keeps the fixed λ-budget), so fast
//!   and slow learners finish together instead of everyone running the
//!   same step count.
//! * **Deadline-quorum rounds** — reliability feeds failure accounting
//!   (learners that keep missing the quorum deadline decay their
//!   [`PerfProfile::reliability`]).
//! * **`Selector::PacingAware`** — [`PacingRegistry::scores`] ranks
//!   learners by `throughput × reliability` for selection, with the
//!   selector's freshness floor keeping slow sites in rotation.

use crate::proto::TaskMeta;
use std::collections::HashMap;
use std::sync::Mutex;
use std::time::Duration;

/// EWMA smoothing factor for throughput/RTT samples: high enough to
/// track a machine whose load shifts, low enough to ride out jitter.
pub const DEFAULT_EWMA_ALPHA: f64 = 0.4;

/// Cap on how far above the fixed fallback budget a paced budget may
/// go, so one noisy huge throughput sample (e.g. a zero-sleep synthetic
/// trainer's first task) cannot hand a learner a multi-hour budget.
pub const MAX_BUDGET_FACTOR: usize = 100;

/// Accumulated performance history for one learner.
#[derive(Debug, Clone, Default)]
pub struct PerfProfile {
    ewma_steps_per_sec: f64,
    ewma_rtt_us: f64,
    completions: u64,
    failures: u64,
    last_seen_round: u64,
}

impl PerfProfile {
    /// Smoothed local-training throughput, if any completion carried a
    /// usable measurement.
    pub fn steps_per_sec(&self) -> Option<f64> {
        (self.ewma_steps_per_sec > 0.0).then_some(self.ewma_steps_per_sec)
    }

    /// Smoothed dispatch→completion round-trip time.
    pub fn rtt(&self) -> Option<Duration> {
        (self.ewma_rtt_us > 0.0).then(|| Duration::from_micros(self.ewma_rtt_us as u64))
    }

    pub fn completions(&self) -> u64 {
        self.completions
    }

    pub fn failures(&self) -> u64 {
        self.failures
    }

    /// Community round of the learner's most recent completion.
    pub fn last_seen_round(&self) -> u64 {
        self.last_seen_round
    }

    /// Laplace-smoothed completion rate in (0, 1): a fresh learner
    /// starts at 0.5 and converges toward its observed rate, so one
    /// early timeout does not zero a site out forever.
    pub fn reliability(&self) -> f64 {
        (self.completions + 1) as f64 / (self.completions + self.failures + 2) as f64
    }

    /// Selection score: throughput discounted by reliability. Learners
    /// with no throughput measurement score 0 (the selector's freshness
    /// floor — not this score — is what gets them scheduled).
    pub fn score(&self) -> f64 {
        self.steps_per_sec().unwrap_or(0.0) * self.reliability()
    }
}

/// Extract a steps-per-second measurement from completion telemetry:
/// the explicit `steps_per_sec` field when the peer filled it, else
/// derived from `completed_steps / train_wall_time_us`, else from the
/// legacy per-batch time (pre-v5 peers).
pub fn steps_per_sec_of(meta: &TaskMeta) -> Option<f64> {
    if meta.steps_per_sec > 0.0 {
        return Some(meta.steps_per_sec);
    }
    if meta.completed_steps > 0 && meta.train_wall_time_us > 0 {
        return Some(meta.completed_steps as f64 / (meta.train_wall_time_us as f64 / 1e6));
    }
    if meta.train_time_per_batch_us > 0 && meta.completed_steps > 0 {
        return Some(1e6 / meta.train_time_per_batch_us as f64);
    }
    None
}

/// Per-learner profile registry. Lives on the controller next to the
/// data-plane gauges; every lock here is leaf-level (never held across
/// a call into `CtrlState`).
pub struct PacingRegistry {
    alpha: f64,
    profiles: Mutex<HashMap<String, PerfProfile>>,
}

impl Default for PacingRegistry {
    fn default() -> PacingRegistry {
        PacingRegistry::new(DEFAULT_EWMA_ALPHA)
    }
}

impl PacingRegistry {
    pub fn new(alpha: f64) -> PacingRegistry {
        PacingRegistry { alpha: alpha.clamp(0.01, 1.0), profiles: Mutex::new(HashMap::new()) }
    }

    /// Fold one task completion into the learner's profile.
    pub fn observe_completion(
        &self,
        learner_id: &str,
        meta: &TaskMeta,
        rtt: Option<Duration>,
        round: u64,
    ) {
        let sps = steps_per_sec_of(meta);
        let mut profiles = self.profiles.lock().unwrap();
        let p = profiles.entry(learner_id.to_string()).or_default();
        if let Some(sps) = sps {
            p.ewma_steps_per_sec = if p.ewma_steps_per_sec > 0.0 {
                self.alpha * sps + (1.0 - self.alpha) * p.ewma_steps_per_sec
            } else {
                sps
            };
        }
        if let Some(rtt) = rtt {
            // Floor at 1µs so an in-proc sub-microsecond sample still
            // registers as "measured".
            let us = (rtt.as_micros() as f64).max(1.0);
            p.ewma_rtt_us = if p.ewma_rtt_us > 0.0 {
                self.alpha * us + (1.0 - self.alpha) * p.ewma_rtt_us
            } else {
                us
            };
        }
        p.completions += 1;
        p.last_seen_round = p.last_seen_round.max(round);
    }

    /// Note a task the learner failed to complete (round timeout, missed
    /// quorum deadline, dispatch failure).
    pub fn observe_failure(&self, learner_id: &str) {
        let mut profiles = self.profiles.lock().unwrap();
        profiles.entry(learner_id.to_string()).or_default().failures += 1;
    }

    /// Drop a learner's history (deregistration).
    pub fn remove(&self, learner_id: &str) {
        self.profiles.lock().unwrap().remove(learner_id);
    }

    /// Profile snapshot for one learner.
    pub fn profile(&self, learner_id: &str) -> Option<PerfProfile> {
        self.profiles.lock().unwrap().get(learner_id).cloned()
    }

    /// Smoothed throughput for one learner.
    pub fn throughput(&self, learner_id: &str) -> Option<f64> {
        self.profiles.lock().unwrap().get(learner_id).and_then(|p| p.steps_per_sec())
    }

    /// Selection scores for every profiled learner (see
    /// [`PerfProfile::score`]).
    pub fn scores(&self) -> HashMap<String, f64> {
        self.profiles
            .lock()
            .unwrap()
            .iter()
            .map(|(id, p)| (id.clone(), p.score()))
            .collect()
    }

    pub fn len(&self) -> usize {
        self.profiles.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.profiles.lock().unwrap().is_empty()
    }

    /// Per-learner semi-sync step budgets for `ids`.
    ///
    /// `fallback_steps` is the fixed λ-scaled budget (`λ ×
    /// steps-per-epoch`) every learner gets today. The paced budget is
    /// `budget_i = t_target · throughput_i` with `t_target =
    /// fallback_steps / min_throughput` — the wall clock the *slowest
    /// profiled participant* needs for the fixed budget — so the
    /// slowest learner keeps exactly `fallback_steps` and every faster
    /// learner trains proportionally more, all finishing together.
    /// Learners with no profile get `fallback_steps` (the fixed-budget
    /// fallback for unseen learners); budgets are clamped to
    /// `[1, fallback_steps × MAX_BUDGET_FACTOR]`.
    pub fn step_budgets<S: AsRef<str>>(&self, ids: &[S], fallback_steps: usize) -> Vec<usize> {
        let fallback = fallback_steps.max(1);
        let profiles = self.profiles.lock().unwrap();
        let throughputs: Vec<Option<f64>> = ids
            .iter()
            .map(|id| profiles.get(id.as_ref()).and_then(|p| p.steps_per_sec()))
            .collect();
        let Some(min_tp) = throughputs
            .iter()
            .flatten()
            .copied()
            .fold(None::<f64>, |acc, t| Some(acc.map_or(t, |a| a.min(t))))
        else {
            return vec![fallback; ids.len()];
        };
        let t_target = fallback as f64 / min_tp.max(f64::MIN_POSITIVE);
        let cap = fallback.saturating_mul(MAX_BUDGET_FACTOR);
        throughputs
            .into_iter()
            .map(|tp| match tp {
                Some(tp) => ((t_target * tp).round() as usize).clamp(1, cap),
                None => fallback,
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta(steps_per_sec: f64) -> TaskMeta {
        TaskMeta { steps_per_sec, completed_steps: 10, ..Default::default() }
    }

    #[test]
    fn ewma_converges_to_a_constant_signal() {
        let reg = PacingRegistry::default();
        for _ in 0..50 {
            reg.observe_completion("a", &meta(120.0), None, 1);
        }
        let tp = reg.throughput("a").unwrap();
        assert!((tp - 120.0).abs() < 1e-6, "{tp}");
    }

    #[test]
    fn ewma_stays_within_sample_envelope() {
        // Property: for any bounded sample stream, the EWMA never
        // leaves [min, max] of the samples seen so far.
        let reg = PacingRegistry::default();
        let mut rng = crate::util::Rng::new(7);
        let (mut lo, mut hi) = (f64::INFINITY, 0.0f64);
        for _ in 0..200 {
            let s = 10.0 + 990.0 * rng.next_f64();
            lo = lo.min(s);
            hi = hi.max(s);
            reg.observe_completion("a", &meta(s), None, 1);
            let tp = reg.throughput("a").unwrap();
            assert!(tp >= lo - 1e-9 && tp <= hi + 1e-9, "{tp} outside [{lo}, {hi}]");
        }
    }

    #[test]
    fn throughput_derives_from_wall_time_when_not_explicit() {
        let reg = PacingRegistry::default();
        let m = TaskMeta {
            completed_steps: 50,
            train_wall_time_us: 2_000_000, // 50 steps in 2 s = 25/s
            ..Default::default()
        };
        reg.observe_completion("a", &m, None, 1);
        assert!((reg.throughput("a").unwrap() - 25.0).abs() < 1e-9);
        // Legacy (pre-v5) peer: only per-batch time.
        let reg = PacingRegistry::default();
        let m = TaskMeta {
            completed_steps: 5,
            train_time_per_batch_us: 10_000, // 100 steps/s
            ..Default::default()
        };
        reg.observe_completion("b", &m, None, 1);
        assert!((reg.throughput("b").unwrap() - 100.0).abs() < 1e-9);
    }

    #[test]
    fn reliability_smooths_and_decays_on_failures() {
        let reg = PacingRegistry::default();
        reg.observe_completion("a", &meta(10.0), None, 1);
        let fresh = reg.profile("a").unwrap().reliability();
        assert!(fresh > 0.5, "{fresh}");
        for _ in 0..8 {
            reg.observe_failure("a");
        }
        let decayed = reg.profile("a").unwrap().reliability();
        assert!(decayed < 0.25, "{decayed}");
        // Never reaches 0 or 1 (Laplace smoothing).
        assert!(decayed > 0.0);
        // A failure-only learner still has a profile (and a score of 0:
        // no throughput measurement yet).
        reg.observe_failure("ghost");
        assert_eq!(reg.profile("ghost").unwrap().score(), 0.0);
    }

    #[test]
    fn rtt_ewma_accumulates() {
        let reg = PacingRegistry::default();
        reg.observe_completion("a", &meta(10.0), Some(Duration::from_millis(40)), 1);
        reg.observe_completion("a", &meta(10.0), Some(Duration::from_millis(60)), 2);
        let rtt = reg.profile("a").unwrap().rtt().unwrap();
        assert!(rtt > Duration::from_millis(40) && rtt < Duration::from_millis(60), "{rtt:?}");
        assert_eq!(reg.profile("a").unwrap().last_seen_round(), 2);
    }

    #[test]
    fn unseen_learners_fall_back_to_the_fixed_budget() {
        let reg = PacingRegistry::default();
        let ids = ["a", "b"];
        assert_eq!(reg.step_budgets(&ids, 10), vec![10, 10]);
        // One profiled learner: it anchors t_target, unseen stays fixed.
        reg.observe_completion("a", &meta(100.0), None, 1);
        assert_eq!(reg.step_budgets(&ids, 10), vec![10, 10]);
    }

    #[test]
    fn skewed_fleet_budgets_equalize_wall_clock() {
        let reg = PacingRegistry::default();
        // 10× throughput skew.
        for _ in 0..5 {
            reg.observe_completion("slow", &meta(20.0), None, 1);
            reg.observe_completion("mid", &meta(50.0), None, 1);
            reg.observe_completion("fast", &meta(200.0), None, 1);
        }
        let ids = ["slow", "mid", "fast"];
        let budgets = reg.step_budgets(&ids, 10);
        // Slowest keeps the fixed budget; faster learners scale up.
        assert_eq!(budgets[0], 10);
        assert_eq!(budgets[1], 25);
        assert_eq!(budgets[2], 100);
        // Equal modeled wall clock: budget_i / throughput_i ≈ t_target.
        let t: Vec<f64> = budgets
            .iter()
            .zip([20.0, 50.0, 200.0])
            .map(|(b, tp)| *b as f64 / tp)
            .collect();
        for w in &t {
            assert!((w - t[0]).abs() / t[0] < 0.1, "wall clocks diverge: {t:?}");
        }
    }

    #[test]
    fn budgets_are_capped_and_floored() {
        let reg = PacingRegistry::default();
        reg.observe_completion("slow", &meta(0.001), None, 1);
        reg.observe_completion("fast", &meta(1e9), None, 1);
        let budgets = reg.step_budgets(&["slow", "fast"], 10);
        assert_eq!(budgets[0], 10);
        assert_eq!(budgets[1], 10 * MAX_BUDGET_FACTOR);
        assert!(budgets.iter().all(|b| *b >= 1));
    }

    #[test]
    fn remove_forgets_a_learner() {
        let reg = PacingRegistry::default();
        reg.observe_completion("a", &meta(10.0), None, 1);
        assert_eq!(reg.len(), 1);
        reg.remove("a");
        assert!(reg.is_empty());
        assert!(reg.throughput("a").is_none());
    }
}
