//! The federation controller — "the first-class citizen of the system".
//!
//! Owns the community model, the learner registry, the model store, the
//! aggregation rule/backend, and the round lifecycle state. It is exposed
//! to the network as a [`Service`] handling the Appendix-B RPCs
//! (`Register`, `MarkTaskCompleted`, heartbeats, …); the round-driving
//! logic lives in [`scheduling`] (sync / semi-sync / async protocols).

pub mod aggregation;
pub mod scheduling;
pub mod selector;
pub mod store;

use crate::config::{FederationEnv, Protocol, SecureSpec};
use crate::metrics::{FedOp, OpMetrics};
use crate::net::{ClientConn, Psk, Service};
use crate::proto::wire::{fnv1a64, FNV64_INIT};
use crate::proto::{
    ErrorCode, Message, ModelProto, StreamPurpose, TaskMeta, TensorLayoutProto, PROTO_VERSION,
};
use crate::tensor::{decode_elems_into, ByteOrder, DType, Tensor, TensorModel};
use crate::util::{log_debug, log_info, Stopwatch, ThreadPool};
use aggregation::{Backend, Contribution, ScratchArena};
use anyhow::{bail, Context, Result};
use selector::Selector;
use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;
use store::{ModelStore, StoredModel};

/// Caps on the inbound data plane, so a buggy or hostile peer cannot
/// grow controller memory without bound: concurrent open streams, the
/// wire payload one stream may announce, the *aggregate* wire payload
/// announced across all open streams (decoded f32 buffers can be up to
/// 2× the wire size for bf16 payloads), and how long an idle stream
/// may sit before being reclaimed (a learner that dies between `Begin`
/// and `End` must not pin its buffers — or a registry slot — forever).
const MAX_OPEN_STREAMS: usize = 256;
const MAX_STREAM_BYTES: usize = 1 << 30; // 1 GiB wire payload per stream
const MAX_TOTAL_STREAM_BYTES: usize = 4 << 30; // 4 GiB announced across streams
const STREAM_IDLE_TIMEOUT: Duration = Duration::from_secs(300);

/// A registered learner as seen by the controller.
pub struct LearnerHandle {
    pub id: String,
    pub endpoint: String,
    pub num_samples: usize,
    pub index: usize,
    conn: Mutex<Option<Box<dyn ClientConn>>>,
}

impl LearnerHandle {
    pub fn new(id: String, endpoint: String, num_samples: usize, index: usize) -> LearnerHandle {
        LearnerHandle { id, endpoint, num_samples, index, conn: Mutex::new(None) }
    }

    /// RPC to this learner, (re)connecting lazily. The per-learner lock
    /// serializes concurrent calls onto one connection.
    pub fn rpc(&self, psk: Psk, msg: &Message) -> Result<Message> {
        self.rpc_timed(psk, msg, std::time::Instant::now()).map(|(m, _)| m)
    }

    /// RPC that also reports *when* (relative to `origin`) the send
    /// (dispatch) phase finished, separate from the reply wait.
    pub fn rpc_timed(
        &self,
        psk: Psk,
        msg: &Message,
        origin: std::time::Instant,
    ) -> Result<(Message, Duration)> {
        self.rpc_inner(psk, RawOrMsg::Msg(msg), origin)
    }

    /// RPC with pre-encoded request bytes (broadcast fast path: the bytes
    /// are shared across all learners of a round — §Perf).
    pub fn rpc_raw_timed(
        &self,
        psk: Psk,
        bytes: &[u8],
        origin: std::time::Instant,
    ) -> Result<(Message, Duration)> {
        self.rpc_inner(psk, RawOrMsg::Raw(bytes), origin)
    }

    fn rpc_inner(
        &self,
        psk: Psk,
        req: RawOrMsg<'_>,
        origin: std::time::Instant,
    ) -> Result<(Message, Duration)> {
        let mut guard = self.conn.lock().unwrap();
        if guard.is_none() {
            *guard = Some(
                crate::net::connect(&self.endpoint, psk)
                    .with_context(|| format!("connecting to learner {}", self.id))?,
            );
        }
        let conn = guard.as_mut().unwrap();
        let send_res = match req {
            RawOrMsg::Msg(m) => conn.send(m),
            RawOrMsg::Raw(b) => conn.send_raw(b),
        };
        let sent_at = origin.elapsed();
        let result = send_res.and_then(|_| conn.recv());
        match result {
            Ok(reply) => Ok((reply, sent_at)),
            Err(e) => {
                *guard = None; // force reconnect next time
                Err(e)
            }
        }
    }
}

enum RawOrMsg<'a> {
    Msg(&'a Message),
    Raw(&'a [u8]),
}

/// Completion record delivered by `MarkTaskCompleted`.
struct RoundState {
    #[allow(dead_code)]
    round: u64,
    expecting: HashSet<String>,
    arrived: Vec<String>,
}

/// An in-flight inbound model stream: the data-plane accumulator that
/// becomes a [`Contribution`] (or the community model) at `End`.
///
/// Buffers are pre-sized from the `Begin` layout and drawn from the
/// aggregation backend's [`ScratchArena`] when it has one, so a
/// steady-state streamed round recycles the same buffers the previous
/// community model vacated. Chunks decode **on arrival**, directly into
/// the partially-filled tensors — the controller never holds a
/// whole-model wire buffer, and none of this touches the `CtrlState`
/// mutex until the final, already-decoded hand-off.
struct StreamTensor {
    name: String,
    shape: Vec<usize>,
    dtype: DType,
    order: ByteOrder,
    elems: usize,
}

struct ModelStream {
    purpose: StreamPurpose,
    task_id: u64,
    learner_id: String,
    meta: TaskMeta,
    /// Announced structure, one entry per tensor.
    layout: Vec<StreamTensor>,
    /// Decoded output buffers, arena-drawn when available.
    bufs: Vec<Vec<f32>>,
    /// Elements decoded so far, per tensor.
    filled: Vec<usize>,
    /// Tensor currently being filled.
    cur_tensor: usize,
    /// Wire payload bytes consumed so far / expected in total.
    received: usize,
    expected: usize,
    next_seq: u64,
    /// Partial-element bytes straddling a chunk boundary (< element size).
    carry: Vec<u8>,
    /// Running FNV-1a 64 over the payload bytes.
    digest: u64,
    /// Arena to return `bufs` to if the stream dies.
    scratch: Option<Arc<ScratchArena>>,
    /// Last `Begin`/`Chunk` arrival; idle streams past
    /// [`STREAM_IDLE_TIMEOUT`] are garbage-collected.
    last_activity: std::time::Instant,
    /// Set by [`ModelStream::recycle`]: the buffers are gone. A chunk
    /// handler that raced the close (it cloned the registry `Arc`
    /// before removal) must fail gracefully instead of indexing the
    /// drained `bufs`.
    dead: bool,
}

impl ModelStream {
    /// Fold one chunk's bytes into the partial model.
    fn ingest(&mut self, mut bytes: &[u8]) -> Result<()> {
        if self.received + bytes.len() > self.expected {
            bail!(
                "stream overrun: {} + {} > expected {}",
                self.received,
                bytes.len(),
                self.expected
            );
        }
        self.digest = fnv1a64(self.digest, bytes);
        self.received += bytes.len();
        while !bytes.is_empty() {
            // Advance past tensors that are already full (zero-element
            // tensors fall through immediately).
            while self.cur_tensor < self.layout.len()
                && self.filled[self.cur_tensor] == self.layout[self.cur_tensor].elems
            {
                self.cur_tensor += 1;
            }
            let t = self.cur_tensor;
            if t >= self.layout.len() {
                bail!("stream bytes beyond announced layout");
            }
            let (dtype, order, elems) =
                (self.layout[t].dtype, self.layout[t].order, self.layout[t].elems);
            let esz = dtype.size_bytes();
            // Complete a partial element left over from the last chunk.
            if !self.carry.is_empty() {
                let need = esz - self.carry.len();
                let take = need.min(bytes.len());
                self.carry.extend_from_slice(&bytes[..take]);
                bytes = &bytes[take..];
                if self.carry.len() == esz {
                    let idx = self.filled[t];
                    let carry = std::mem::take(&mut self.carry);
                    decode_elems_into(dtype, order, &carry, &mut self.bufs[t][idx..idx + 1]);
                    self.filled[t] += 1;
                }
                continue;
            }
            // Bulk-decode whole elements into this tensor's buffer.
            let max_bytes = (elems - self.filled[t]) * esz;
            let take = bytes.len().min(max_bytes);
            let whole = (take / esz) * esz;
            if whole > 0 {
                let lo = self.filled[t];
                let n = whole / esz;
                decode_elems_into(dtype, order, &bytes[..whole], &mut self.bufs[t][lo..lo + n]);
                self.filled[t] += n;
            }
            self.carry.extend_from_slice(&bytes[whole..take]);
            bytes = &bytes[take..];
        }
        Ok(())
    }

    /// Finish the stream, returning the decoded model.
    fn finish(mut self, digest: u64) -> std::result::Result<TensorModel, (Self, anyhow::Error)> {
        if self.received != self.expected {
            let e = anyhow::anyhow!(
                "stream truncated: got {} of {} payload bytes",
                self.received,
                self.expected
            );
            return Err((self, e));
        }
        if !self.carry.is_empty() {
            let e = anyhow::anyhow!("stream ends mid-element ({} carry bytes)", self.carry.len());
            return Err((self, e));
        }
        if digest != self.digest {
            let e = anyhow::anyhow!(
                "stream digest mismatch: sender {:#018x}, receiver {:#018x}",
                digest,
                self.digest
            );
            return Err((self, e));
        }
        let bufs = std::mem::take(&mut self.bufs);
        let tensors = self
            .layout
            .iter()
            .zip(bufs)
            .map(|(t, data)| Tensor::new(t.name.clone(), t.shape.clone(), data))
            .collect();
        Ok(TensorModel::new(tensors))
    }

    /// Hand every buffer back to the arena (stream abandoned or failed)
    /// and mark the stream dead for any handler still holding its `Arc`.
    fn recycle(&mut self) {
        self.dead = true;
        if let Some(scratch) = &self.scratch {
            for buf in self.bufs.drain(..) {
                scratch.recycle(buf);
            }
        } else {
            self.bufs.clear();
        }
    }
}

struct CtrlState {
    /// Community model, shared by pointer: schedulers snapshot it, the
    /// store hands back `Arc`s, and aggregation reads through them — the
    /// controller never deep-copies a model on the hot path.
    community: Option<Arc<TensorModel>>,
    community_round: u64,
    rule: Box<dyn aggregation::AggregationRule>,
    store: Box<dyn ModelStore>,
    learners: Vec<Arc<LearnerHandle>>,
    last_participation: HashMap<String, u64>,
    /// Round each learner's current task was dispatched at (staleness).
    dispatch_round: HashMap<String, u64>,
    round: Option<RoundState>,
    /// Async protocol: community updates applied so far.
    async_updates: u64,
    /// Async protocol: learners with a task currently in flight.
    outstanding: HashSet<String>,
}

/// Injected XLA aggregation kernel (compiled via the runtime module).
pub use aggregation::XlaAggFn;

/// The federation controller.
pub struct Controller {
    pub env: FederationEnv,
    pub psk: Psk,
    backend: Backend,
    state: Mutex<CtrlState>,
    round_cv: Condvar,
    metrics: Mutex<OpMetrics>,
    dispatch_pool: ThreadPool,
    shutdown: AtomicBool,
    xla_slot: Mutex<Option<XlaAggFn>>,
    /// Inbound data-plane streams, keyed by stream id. Deliberately
    /// *outside* the `CtrlState` mutex: chunk ingest for one learner
    /// never contends with the round barrier or another learner's
    /// stream (per-stream locks below the registry lock).
    streams: Mutex<HashMap<u64, Arc<Mutex<ModelStream>>>>,
    /// Wire bytes announced by currently-open streams (admission budget
    /// against [`MAX_TOTAL_STREAM_BYTES`]).
    open_stream_bytes: AtomicUsize,
    /// Wire-payload bytes currently held for model ingest (one-shot
    /// protos being decoded + stream chunks in flight), plus the
    /// high-water mark. This is the "second whole-model buffer" the
    /// data plane eliminates; tests assert the streamed bound.
    wire_in_flight: AtomicUsize,
    wire_peak: AtomicUsize,
}

impl Controller {
    pub fn new(env: FederationEnv, psk: Psk) -> Result<Arc<Controller>> {
        env.validate()?;
        if env.secure != SecureSpec::None && !matches!(env.transport, crate::config::TransportKind::InProc) {
            bail!("secure aggregation is only wired for in-process simulation (see DESIGN.md)");
        }
        let backend = Backend::from_spec(&env.aggregation);
        let rule = aggregation::rule_from_spec(&env.aggregation)?;
        let dispatch_threads = env.learners.clamp(1, 16);
        Ok(Arc::new(Controller {
            env,
            psk,
            backend,
            state: Mutex::new(CtrlState {
                community: None,
                community_round: 0,
                rule,
                store: Box::new(store::InMemoryStore::new()),
                learners: Vec::new(),
                last_participation: HashMap::new(),
                dispatch_round: HashMap::new(),
                round: None,
                async_updates: 0,
                outstanding: HashSet::new(),
            }),
            round_cv: Condvar::new(),
            metrics: Mutex::new(OpMetrics::new()),
            dispatch_pool: ThreadPool::new(dispatch_threads),
            shutdown: AtomicBool::new(false),
            xla_slot: Mutex::new(None),
            streams: Mutex::new(HashMap::new()),
            open_stream_bytes: AtomicUsize::new(0),
            wire_in_flight: AtomicUsize::new(0),
            wire_peak: AtomicUsize::new(0),
        }))
    }

    /// Replace the model store (e.g. [`store::OnDiskStore`]).
    pub fn set_store(&self, s: Box<dyn ModelStore>) {
        self.state.lock().unwrap().store = s;
    }

    /// Wire the XLA aggregation backend (injected by `runtime` after the
    /// compiled fedavg kernel is loaded; until then the Xla config choice
    /// falls back to Sequential).
    pub fn set_xla_backend(&self, f: XlaAggFn) {
        *self.xla_slot.lock().unwrap() = Some(f);
    }

    /// Effective backend for aggregation (resolves the Xla slot).
    fn effective_backend(&self) -> Backend {
        if self.env.aggregation.backend == crate::config::AggregationBackend::Xla {
            if let Some(f) = self.xla_slot.lock().unwrap().clone() {
                return Backend::Xla(f);
            }
        }
        self.backend.clone()
    }

    pub fn is_shutdown(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst)
    }

    /// Registered learner count.
    pub fn learner_count(&self) -> usize {
        self.state.lock().unwrap().learners.len()
    }

    /// Wait until `n` learners registered (driver startup barrier).
    pub fn wait_for_learners(&self, n: usize, timeout: Duration) -> Result<()> {
        let deadline = std::time::Instant::now() + timeout;
        let mut state = self.state.lock().unwrap();
        while state.learners.len() < n {
            let remaining = deadline
                .checked_duration_since(std::time::Instant::now())
                .ok_or_else(|| anyhow::anyhow!("timeout waiting for {n} learners"))?;
            let (s, _) = self.round_cv.wait_timeout(state, remaining).unwrap();
            state = s;
        }
        Ok(())
    }

    /// Snapshot of the community model (initialized by `ShipModel`).
    /// Returns a shared pointer — no copy. Callers that keep the snapshot
    /// across an aggregation (schedulers) should drop it once serialized
    /// so the controller can recycle the buffers on replacement.
    pub fn community(&self) -> Option<(Arc<TensorModel>, u64)> {
        let s = self.state.lock().unwrap();
        s.community.clone().map(|m| (m, s.community_round))
    }

    /// Set the community model directly (driver-local initialization).
    pub fn ship_model(&self, model: TensorModel) {
        let mut s = self.state.lock().unwrap();
        s.community = Some(Arc::new(model));
        log_info("controller", "community model initialized");
    }

    /// Register a learner directly (in-proc driver path).
    pub fn register_learner(&self, id: &str, endpoint: &str, num_samples: usize) -> usize {
        let mut s = self.state.lock().unwrap();
        let index = s.learners.len();
        s.learners.push(Arc::new(LearnerHandle::new(
            id.to_string(),
            endpoint.to_string(),
            num_samples,
            index,
        )));
        log_debug("controller", &format!("registered learner {id} at {endpoint} (#{index})"));
        self.round_cv.notify_all();
        index
    }

    fn learners_snapshot(&self) -> Vec<Arc<LearnerHandle>> {
        self.state.lock().unwrap().learners.clone()
    }

    /// Metrics snapshot.
    pub fn metrics(&self) -> OpMetrics {
        self.metrics.lock().unwrap().clone()
    }

    pub(crate) fn record(&self, op: FedOp, d: Duration) {
        self.metrics.lock().unwrap().record(op, d);
    }

    // ---- round plumbing used by `scheduling` -------------------------

    /// Open a round: note who we expect and stamp dispatch rounds.
    fn open_round(&self, round: u64, expecting: &[String]) {
        let mut s = self.state.lock().unwrap();
        for id in expecting {
            s.dispatch_round.insert(id.clone(), round);
            s.last_participation.insert(id.clone(), round);
        }
        s.round = Some(RoundState {
            round,
            expecting: expecting.iter().cloned().collect(),
            arrived: Vec::new(),
        });
    }

    /// Block until all expected completions arrived or `timeout` elapsed.
    /// Returns the learner ids that did arrive.
    fn wait_round_completions(&self, timeout: Duration) -> Vec<String> {
        let deadline = std::time::Instant::now() + timeout;
        let mut s = self.state.lock().unwrap();
        loop {
            let done = match &s.round {
                Some(r) => r.arrived.len() >= r.expecting.len(),
                None => true,
            };
            if done {
                break;
            }
            let Some(remaining) = deadline.checked_duration_since(std::time::Instant::now())
            else {
                break;
            };
            let (guard, _) = self.round_cv.wait_timeout(s, remaining).unwrap();
            s = guard;
        }
        let mut arrived = s.round.as_ref().map(|r| r.arrived.clone()).unwrap_or_default();
        s.round = None;
        // Sort so aggregation order (and thus fp rounding) is independent
        // of completion timing — parallel and sequential runs of the same
        // federation produce bitwise-identical community models.
        arrived.sort();
        arrived
    }

    /// Aggregate `learner_ids`' latest stored models into a new community
    /// model (T4–T7). Returns the new model (shared, not copied).
    ///
    /// Hot-path properties: `current` and every selection from the store
    /// are `Arc` clones — no model is deep-copied — and with the chunked
    /// backend the output is written into recycled scratch buffers, so a
    /// steady-state round performs zero O(params) allocation.
    fn aggregate_from_store(&self, learner_ids: &[String], round: u64) -> Result<Arc<TensorModel>> {
        let backend = self.effective_backend();
        let mut s = self.state.lock().unwrap();
        let current = s
            .community
            .clone()
            .ok_or_else(|| anyhow::anyhow!("no community model shipped"))?;
        let selected = s.store.select_latest(learner_ids)?;
        if selected.is_empty() {
            bail!("round {round}: no completed learner models to aggregate");
        }
        let contributions: Vec<Contribution> = selected
            .iter()
            .map(|m| Contribution {
                model: Arc::clone(&m.model),
                weight: m.meta.num_samples.max(1) as f64,
            })
            .collect();
        let new_model = Arc::new(s.rule.aggregate(&current, &contributions, &backend)?);
        let previous = s.community.replace(Arc::clone(&new_model));
        s.community_round = round;
        // Keep only the freshest model per learner (paper's in-memory
        // assumption; lineage stores are opt-in via set_store + evict).
        s.store.evict(1)?;
        drop(s);
        // Release our handles on the outgoing community model, then hand
        // its buffers back to the arena for the next round's output.
        drop(current);
        if let (Some(prev), Some(scratch)) = (previous, backend.scratch()) {
            scratch.reclaim_model(prev);
        }
        if crate::util::logging::enabled(crate::util::logging::LogLevel::Debug) {
            log_debug(
                "controller",
                &format!(
                    "round {round}: community ‖w‖₂ = {:.6}",
                    aggregation::model_l2_norm(&new_model, &backend)
                ),
            );
        }
        Ok(new_model)
    }

    /// Async protocol: mix one completed local model into the community
    /// model immediately, discounted by staleness (Stripelis 2022b).
    fn async_mix(&self, entry: &StoredModel, alpha: f64) -> Result<u64> {
        let backend = self.effective_backend();
        let mut s = self.state.lock().unwrap();
        let current = s
            .community
            .clone()
            .ok_or_else(|| anyhow::anyhow!("no community model shipped"))?;
        let dispatched = s.dispatch_round.get(&entry.learner_id).copied().unwrap_or(0);
        let staleness = s.community_round.saturating_sub(dispatched) as f64;
        let w = (1.0 + staleness).powf(-alpha) * 0.5;
        let models = [Arc::clone(&current), Arc::clone(&entry.model)];
        let coeffs = [1.0 - w, w];
        let mixed =
            Arc::new(aggregation::WeightedSum::compute(&models, &coeffs, &backend)?);
        let previous = s.community.replace(mixed);
        drop(models);
        drop(current);
        if let (Some(prev), Some(scratch)) = (previous, backend.scratch()) {
            scratch.reclaim_model(prev);
        }
        s.community_round += 1;
        s.async_updates += 1;
        let updates = s.async_updates;
        // Next task for this learner is dispatched against the new round,
        // and the learner is idle until the scheduler re-dispatches.
        let community_round = s.community_round;
        s.dispatch_round.insert(entry.learner_id.clone(), community_round);
        s.outstanding.remove(&entry.learner_id);
        Ok(updates)
    }

    /// Number of async community updates applied so far.
    pub fn async_updates(&self) -> u64 {
        self.state.lock().unwrap().async_updates
    }

    /// Async protocol: does this learner need a fresh task?
    pub(crate) fn learner_needs_task(&self, id: &str) -> bool {
        !self.state.lock().unwrap().outstanding.contains(id)
    }

    /// Async protocol: note that a task is in flight for this learner.
    pub(crate) fn mark_task_outstanding(&self, id: &str) {
        self.state.lock().unwrap().outstanding.insert(id.to_string());
    }

    /// Dispatch one message to `targets` concurrently. The message is
    /// serialized ONCE and the same bytes fan out to every learner
    /// (§Perf: dispatch used to re-encode the full model per learner).
    /// Returns `(dispatch_time, per-learner results)` where
    /// `dispatch_time` is the wall-clock until every request had been
    /// submitted (the paper's "task dispatch time"); the results include
    /// the full reply wait. Used for both train (fire-and-forget + Ack)
    /// and eval (blocking reply) dispatches.
    fn broadcast(
        &self,
        targets: &[Arc<LearnerHandle>],
        msg: &Message,
    ) -> (Duration, Vec<(String, Result<Message>)>) {
        let psk = self.psk;
        let origin = std::time::Instant::now();
        let encoded = msg.encode();
        let results = self.dispatch_pool.parallel_map(targets.len(), |i| {
            let h = &targets[i];
            h.rpc_raw_timed(psk, &encoded, origin)
        });
        // Dispatch completes when the slowest send has finished (offsets
        // are measured from `origin`, so bounded-pool queueing delay is
        // included — as it is in every framework the paper measures).
        let dispatch: Duration = results
            .iter()
            .filter_map(|r| r.as_ref().ok().map(|(_, sent_at)| *sent_at))
            .max()
            .unwrap_or(Duration::ZERO);
        let out = targets
            .iter()
            .zip(results)
            .map(|(h, r)| (h.id.clone(), r.map(|(reply, _)| reply)))
            .collect();
        (dispatch, out)
    }

    /// Select round participants per the env's participation policy.
    fn select_participants(&self, rng: &mut crate::util::Rng) -> Vec<Arc<LearnerHandle>> {
        let learners = self.learners_snapshot();
        let ids: Vec<String> = learners.iter().map(|l| l.id.clone()).collect();
        let last = self.state.lock().unwrap().last_participation.clone();
        let chosen = Selector::from_participation(self.env.participation).select(&ids, &last, rng);
        let set: HashSet<&String> = chosen.iter().collect();
        learners.into_iter().filter(|l| set.contains(&l.id)).collect()
    }

    // ---- model ingest bookkeeping ------------------------------------

    fn wire_hold(&self, bytes: usize) {
        let now = self.wire_in_flight.fetch_add(bytes, Ordering::SeqCst) + bytes;
        self.wire_peak.fetch_max(now, Ordering::SeqCst);
    }

    fn wire_release(&self, bytes: usize) {
        self.wire_in_flight.fetch_sub(bytes, Ordering::SeqCst);
    }

    /// High-water mark of wire-payload bytes held for model ingest. With
    /// one-shot uploads this reaches `Σ in-flight models' byte size`;
    /// with the streaming data plane it is bounded by
    /// `chunk size × in-flight streams` (asserted end-to-end in
    /// `tests/streaming.rs`).
    pub fn peak_wire_ingest_bytes(&self) -> usize {
        self.wire_peak.load(Ordering::SeqCst)
    }

    /// Streams currently open on the data plane.
    pub fn open_streams(&self) -> usize {
        self.streams.lock().unwrap().len()
    }

    // ---- data plane: inbound model streams ---------------------------
    //
    // Everything here stays off the `CtrlState` mutex; only the final
    // `End` hand-off (already decoded) takes it, exactly like the
    // decode-before-lock one-shot path.

    fn on_stream_begin(
        &self,
        stream_id: u64,
        task_id: u64,
        purpose: StreamPurpose,
        learner_id: String,
        layout: Vec<TensorLayoutProto>,
        meta: TaskMeta,
    ) -> Message {
        if layout.is_empty() {
            return Message::error(ErrorCode::StreamProtocol, "empty stream layout");
        }
        let mut parsed = Vec::with_capacity(layout.len());
        let mut expected = 0usize;
        for t in &layout {
            let elems = match t.elem_count_checked() {
                Ok(n) => n,
                Err(e) => return Message::error(ErrorCode::StreamProtocol, format!("{e:#}")),
            };
            let bytes = match t.byte_len_checked() {
                Ok(n) => n,
                Err(e) => return Message::error(ErrorCode::StreamProtocol, format!("{e:#}")),
            };
            expected = match expected.checked_add(bytes) {
                Some(n) if n <= MAX_STREAM_BYTES => n,
                _ => {
                    return Message::error(
                        ErrorCode::StreamProtocol,
                        format!("stream exceeds {MAX_STREAM_BYTES} payload bytes"),
                    )
                }
            };
            parsed.push(StreamTensor {
                name: t.name.clone(),
                shape: t.shape.clone(),
                dtype: t.dtype,
                order: t.byte_order,
                elems,
            });
        }
        // Admission control runs BEFORE any buffer is allocated, so an
        // unauthenticated `Begin` flood cannot commit memory: reclaim
        // idle streams, then check slot, duplicate id, and the aggregate
        // announced-bytes budget.
        self.gc_idle_streams();
        {
            let streams = self.streams.lock().unwrap();
            if streams.len() >= MAX_OPEN_STREAMS {
                return Message::error(
                    ErrorCode::StreamProtocol,
                    format!("too many open streams (max {MAX_OPEN_STREAMS})"),
                );
            }
            if streams.contains_key(&stream_id) {
                return Message::error(
                    ErrorCode::StreamProtocol,
                    format!("stream id {stream_id:#x} already open"),
                );
            }
        }
        let budget = self.open_stream_bytes.fetch_add(expected, Ordering::SeqCst) + expected;
        if budget > MAX_TOTAL_STREAM_BYTES {
            self.open_stream_bytes.fetch_sub(expected, Ordering::SeqCst);
            return Message::error(
                ErrorCode::StreamProtocol,
                format!("open streams would exceed {MAX_TOTAL_STREAM_BYTES} announced bytes"),
            );
        }
        // Pre-size the decode buffers from the arena (when the backend
        // owns one): a steady-state streamed round re-fills the buffers
        // the previous community model vacated.
        let scratch = self.effective_backend().scratch().cloned();
        let bufs: Vec<Vec<f32>> = parsed
            .iter()
            .map(|t| match &scratch {
                Some(s) => s.take(t.elems),
                None => vec![0.0; t.elems],
            })
            .collect();
        let filled = vec![0usize; parsed.len()];
        let mut stream = ModelStream {
            purpose,
            task_id,
            learner_id,
            meta,
            layout: parsed,
            bufs,
            filled,
            cur_tensor: 0,
            received: 0,
            expected,
            next_seq: 0,
            carry: Vec::new(),
            digest: FNV64_INIT,
            scratch,
            last_activity: std::time::Instant::now(),
            dead: false,
        };
        let mut streams = self.streams.lock().unwrap();
        // Re-check under the lock: a racing Begin may have taken the id
        // or the last slot while we were allocating.
        if streams.len() >= MAX_OPEN_STREAMS || streams.contains_key(&stream_id) {
            drop(streams);
            stream.recycle();
            self.open_stream_bytes.fetch_sub(expected, Ordering::SeqCst);
            return Message::error(
                ErrorCode::StreamProtocol,
                format!("stream id {stream_id:#x} rejected (slot raced away)"),
            );
        }
        streams.insert(stream_id, Arc::new(Mutex::new(stream)));
        Message::Ack { task_id: stream_id, ok: true }
    }

    /// Reclaim streams with no activity for [`STREAM_IDLE_TIMEOUT`]: a
    /// learner that died mid-stream must not pin its buffers or leak a
    /// registry slot until the cap locks streaming out entirely.
    fn gc_idle_streams(&self) {
        let expired: Vec<u64> = {
            let streams = self.streams.lock().unwrap();
            streams
                .iter()
                .filter(|(_, s)| {
                    s.lock().unwrap().last_activity.elapsed() > STREAM_IDLE_TIMEOUT
                })
                .map(|(id, _)| *id)
                .collect()
        };
        for id in expired {
            log_debug("controller", &format!("reclaiming idle stream {id:#x}"));
            self.kill_stream(id);
        }
    }

    fn on_stream_chunk(&self, stream_id: u64, seq: u64, bytes: Vec<u8>) -> Message {
        let Some(stream) = self.streams.lock().unwrap().get(&stream_id).cloned() else {
            return Message::error(
                ErrorCode::StreamProtocol,
                format!("chunk for unknown stream {stream_id:#x}"),
            );
        };
        self.wire_hold(bytes.len());
        let sw = Stopwatch::start();
        let result = {
            let mut s = stream.lock().unwrap();
            if s.dead {
                // We raced a close: the registry entry is already gone
                // and the buffers were recycled.
                Err(anyhow::anyhow!("chunk for a closed stream"))
            } else if seq != s.next_seq {
                Err(anyhow::anyhow!("chunk seq {seq}, expected {}", s.next_seq))
            } else {
                s.last_activity = std::time::Instant::now();
                s.next_seq += 1;
                s.ingest(&bytes)
            }
        };
        self.record(FedOp::Serialization, sw.elapsed());
        self.wire_release(bytes.len());
        match result {
            Ok(()) => Message::Ack { task_id: stream_id, ok: true },
            Err(e) => {
                self.kill_stream(stream_id);
                Message::error(ErrorCode::StreamProtocol, format!("{e:#}"))
            }
        }
    }

    fn on_stream_end(&self, stream_id: u64, digest: u64) -> Message {
        let Some(stream) = self.streams.lock().unwrap().remove(&stream_id) else {
            return Message::error(
                ErrorCode::StreamProtocol,
                format!("end for unknown stream {stream_id:#x}"),
            );
        };
        // Sole holder now (the registry entry is gone; chunk handlers
        // clone the Arc only while the entry exists and hold it briefly).
        let stream = match Arc::try_unwrap(stream) {
            Ok(m) => m.into_inner().unwrap(),
            Err(arc) => {
                // A racing chunk still holds the Arc: a protocol
                // violation (chunks after End); drop the stream.
                let mut s = arc.lock().unwrap();
                self.open_stream_bytes.fetch_sub(s.expected, Ordering::SeqCst);
                s.recycle();
                return Message::error(
                    ErrorCode::StreamProtocol,
                    "stream closed while chunks were in flight",
                );
            }
        };
        self.open_stream_bytes.fetch_sub(stream.expected, Ordering::SeqCst);
        let (purpose, task_id, learner_id, meta) = (
            stream.purpose,
            stream.task_id,
            stream.learner_id.clone(),
            stream.meta.clone(),
        );
        let model = match stream.finish(digest) {
            Ok(m) => m,
            Err((mut s, e)) => {
                s.recycle();
                return Message::error(ErrorCode::StreamProtocol, format!("{e:#}"));
            }
        };
        match purpose {
            StreamPurpose::ShipModel => {
                self.ship_model(model);
                Message::Ack { task_id: stream_id, ok: true }
            }
            StreamPurpose::TaskCompletion => {
                match self.complete_task(task_id, learner_id, model, meta) {
                    Ok(()) => Message::Ack { task_id: stream_id, ok: true },
                    Err(e) => Message::error(ErrorCode::Internal, format!("{e:#}")),
                }
            }
        }
    }

    /// Drop a failed/abandoned stream, recycle its buffers, and return
    /// its announced bytes to the admission budget.
    fn kill_stream(&self, stream_id: u64) {
        if let Some(stream) = self.streams.lock().unwrap().remove(&stream_id) {
            let mut s = stream.lock().unwrap();
            self.open_stream_bytes.fetch_sub(s.expected, Ordering::SeqCst);
            s.recycle();
        }
    }
}

impl Service for Controller {
    fn handle(&self, msg: Message) -> Message {
        if self.is_shutdown() {
            return Message::error(ErrorCode::Unavailable, "controller is shut down");
        }
        match msg {
            Message::Hello { proto_version } => {
                if proto_version == PROTO_VERSION {
                    Message::HelloAck {
                        proto_version: PROTO_VERSION,
                        component: "controller".into(),
                    }
                } else {
                    Message::error(
                        ErrorCode::VersionMismatch,
                        format!("controller speaks v{PROTO_VERSION}, peer v{proto_version}"),
                    )
                }
            }
            Message::Register { learner_id, host, port, num_samples } => {
                // `host` may be a full endpoint (inproc://… or tcp://…)
                // or a bare hostname + port pair.
                let endpoint = if host.contains("://") {
                    host
                } else {
                    format!("tcp://{host}:{port}")
                };
                let idx = self.register_learner(&learner_id, &endpoint, num_samples);
                Message::RegisterAck { accepted: true, assigned_index: idx }
            }
            Message::ShipModel { model } => {
                // Decode outside every lock; the wire buffer is released
                // before the model is installed.
                let wire = model.byte_size();
                self.wire_hold(wire);
                let decoded = model.to_model();
                drop(model);
                self.wire_release(wire);
                match decoded {
                    Ok(m) => {
                        self.ship_model(m);
                        Message::Ack { task_id: 0, ok: true }
                    }
                    Err(e) => Message::error(ErrorCode::InvalidModel, format!("bad model: {e:#}")),
                }
            }
            Message::MarkTaskCompleted { task_id, learner_id, model, meta } => {
                // One-shot path: decode before touching any controller
                // lock. The gauge brackets exactly the wire buffer's
                // lifetime (held only while decoding) so the streamed
                // vs one-shot comparison in tests/streaming.rs measures
                // real memory, not an accounting artifact.
                let sw = Stopwatch::start();
                let wire = model.byte_size();
                self.wire_hold(wire);
                let decoded = model.to_model();
                drop(model);
                self.wire_release(wire);
                self.record(FedOp::Serialization, sw.elapsed());
                match decoded {
                    Err(e) => {
                        Message::error(ErrorCode::InvalidModel, format!("bad model: {e:#}"))
                    }
                    Ok(m) => match self.complete_task(task_id, learner_id, m, meta) {
                        Ok(()) => Message::Ack { task_id, ok: true },
                        Err(e) => Message::error(ErrorCode::Internal, format!("{e:#}")),
                    },
                }
            }
            Message::ModelStreamBegin {
                stream_id,
                task_id,
                round: _,
                purpose,
                learner_id,
                layout,
                meta,
            } => self.on_stream_begin(stream_id, task_id, purpose, learner_id, layout, meta),
            Message::ModelChunk { stream_id, seq, bytes } => {
                self.on_stream_chunk(stream_id, seq, bytes)
            }
            Message::ModelStreamEnd { stream_id, digest } => {
                self.on_stream_end(stream_id, digest)
            }
            Message::Heartbeat { .. } => {
                // The driver probes every `heartbeat_ms`, which makes
                // this a natural periodic sweep for streams abandoned by
                // a dead peer (otherwise they'd only be reclaimed when
                // the next streamed upload begins).
                self.gc_idle_streams();
                Message::HeartbeatAck { component: "controller".into(), healthy: true }
            }
            Message::GetModel => {
                // Snapshot under the lock, serialize after releasing it —
                // encoding a 10M-param model must not stall completions.
                match self.community() {
                    Some((m, round)) => Message::ModelReply {
                        model: ModelProto::from_model(&m, DType::F32, ByteOrder::Little),
                        round,
                    },
                    None => Message::error(ErrorCode::NotFound, "no community model"),
                }
            }
            Message::Shutdown => {
                self.shutdown.store(true, Ordering::SeqCst);
                self.round_cv.notify_all();
                Message::Ack { task_id: 0, ok: true }
            }
            other => {
                Message::error(ErrorCode::Unsupported, format!("unexpected {}", other.kind()))
            }
        }
    }
}

impl Controller {
    /// Decoded-model completion path shared by the one-shot and
    /// streaming ingests: store the model (T4–T5) and either tick the
    /// round barrier (sync/semi-sync) or mix immediately (async).
    fn complete_task(
        &self,
        _task_id: u64,
        learner_id: String,
        model: TensorModel,
        meta: TaskMeta,
    ) -> Result<()> {
        let entry = StoredModel {
            learner_id: learner_id.clone(),
            round: self.state.lock().unwrap().community_round,
            meta,
            model: Arc::new(model),
        };

        match self.env.protocol {
            Protocol::Asynchronous { staleness_alpha } => {
                let sw = Stopwatch::start();
                // Store (for inspection/metrics parity with sync).
                {
                    let mut s = self.state.lock().unwrap();
                    let insert_sw = Stopwatch::start();
                    s.store.insert(entry.clone())?;
                    s.store.evict(1)?;
                    drop(s);
                    self.record(FedOp::StoreInsert, insert_sw.elapsed());
                }
                self.async_mix(&entry, staleness_alpha)?;
                self.record(FedOp::Aggregation, sw.elapsed());
                self.round_cv.notify_all();
                Ok(())
            }
            _ => {
                let mut s = self.state.lock().unwrap();
                let insert_sw = Stopwatch::start();
                s.store.insert(entry)?;
                let insert_time = insert_sw.elapsed();
                if let Some(r) = s.round.as_mut() {
                    if r.expecting.contains(&learner_id)
                        && !r.arrived.iter().any(|a| a == &learner_id)
                    {
                        r.arrived.push(learner_id);
                    }
                }
                drop(s);
                self.record(FedOp::StoreInsert, insert_time);
                self.round_cv.notify_all();
                Ok(())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{FederationEnv, ModelSpec};
    use crate::util::Rng;

    fn env() -> FederationEnv {
        FederationEnv::builder("ctrl-test")
            .learners(3)
            .model(ModelSpec::mlp(4, 2, 8))
            .build()
    }

    fn model(seed: u64) -> TensorModel {
        let layout = ModelSpec::mlp(4, 2, 8).tensor_layout();
        TensorModel::random_init(&layout, &mut Rng::new(seed))
    }

    #[test]
    fn register_and_ship_via_service() {
        let ctrl = Controller::new(env(), None).unwrap();
        let reply = ctrl.handle(Message::Register {
            learner_id: "l0".into(),
            host: "inproc://l0".into(),
            port: 0,
            num_samples: 100,
        });
        assert_eq!(reply, Message::RegisterAck { accepted: true, assigned_index: 0 });
        assert_eq!(ctrl.learner_count(), 1);

        let m = model(1);
        let reply = ctrl.handle(Message::ShipModel {
            model: ModelProto::from_model(&m, DType::F32, ByteOrder::Little),
        });
        assert_eq!(reply, Message::Ack { task_id: 0, ok: true });
        let (community, round) = ctrl.community().unwrap();
        assert_eq!(round, 0);
        assert!(community.max_abs_diff(&m) == 0.0);
    }

    #[test]
    fn completion_barrier_counts_expected_only() {
        let ctrl = Controller::new(env(), None).unwrap();
        ctrl.ship_model(model(1));
        ctrl.open_round(1, &["a".into(), "b".into()]);
        // Unexpected learner does not tick the barrier.
        let mp = ModelProto::from_model(&model(2), DType::F32, ByteOrder::Little);
        ctrl.handle(Message::MarkTaskCompleted {
            task_id: 1,
            learner_id: "zzz".into(),
            model: mp.clone(),
            meta: TaskMeta { num_samples: 10, ..Default::default() },
        });
        ctrl.handle(Message::MarkTaskCompleted {
            task_id: 1,
            learner_id: "a".into(),
            model: mp.clone(),
            meta: TaskMeta { num_samples: 10, ..Default::default() },
        });
        // Duplicate completion counted once.
        ctrl.handle(Message::MarkTaskCompleted {
            task_id: 1,
            learner_id: "a".into(),
            model: mp.clone(),
            meta: TaskMeta { num_samples: 10, ..Default::default() },
        });
        let arrived = ctrl.wait_round_completions(Duration::from_millis(50));
        assert_eq!(arrived, vec!["a".to_string()]); // timeout path
    }

    #[test]
    fn aggregate_from_store_updates_community() {
        let ctrl = Controller::new(env(), None).unwrap();
        ctrl.ship_model(model(1));
        let mp_a = ModelProto::from_model(&model(2), DType::F32, ByteOrder::Little);
        let mp_b = ModelProto::from_model(&model(3), DType::F32, ByteOrder::Little);
        ctrl.open_round(1, &["a".into(), "b".into()]);
        for (id, mp) in [("a", mp_a), ("b", mp_b)] {
            ctrl.handle(Message::MarkTaskCompleted {
                task_id: 1,
                learner_id: id.into(),
                model: mp,
                meta: TaskMeta { num_samples: 100, ..Default::default() },
            });
        }
        let arrived = ctrl.wait_round_completions(Duration::from_secs(1));
        assert_eq!(arrived.len(), 2);
        let new_model = ctrl.aggregate_from_store(&arrived, 1).unwrap();
        let (community, round) = ctrl.community().unwrap();
        assert_eq!(round, 1);
        assert_eq!(community, new_model);
        // Mean of the two models.
        let expect = 0.5 * model(2).tensors[0].data[0] + 0.5 * model(3).tensors[0].data[0];
        assert!((new_model.tensors[0].data[0] - expect).abs() < 1e-5);
    }

    #[test]
    fn chunked_steady_state_rounds_do_not_allocate_output_buffers() {
        use crate::config::{AggregationBackend, AggregationSpec};
        let mut e = env();
        e.aggregation = AggregationSpec {
            backend: AggregationBackend::Chunked,
            threads: 2,
            ..Default::default()
        };
        let ctrl = Controller::new(e, None).unwrap();
        ctrl.ship_model(model(1));
        let scratch = Arc::clone(ctrl.backend.scratch().expect("chunked backend"));
        let tensor_count = model(1).tensor_count();
        let mut allocs_per_round = Vec::new();
        for round in 1..=5u64 {
            ctrl.open_round(round, &["a".into(), "b".into()]);
            for (i, id) in ["a", "b"].into_iter().enumerate() {
                let m = model(100 + round * 2 + i as u64);
                ctrl.handle(Message::MarkTaskCompleted {
                    task_id: round,
                    learner_id: id.into(),
                    model: ModelProto::from_model(&m, DType::F32, ByteOrder::Little),
                    meta: TaskMeta { num_samples: 10, ..Default::default() },
                });
            }
            let arrived = ctrl.wait_round_completions(Duration::from_secs(1));
            assert_eq!(arrived.len(), 2);
            ctrl.aggregate_from_store(&arrived, round).unwrap();
            allocs_per_round.push(scratch.fresh_allocations());
        }
        // Round 1 pays one buffer per output tensor; every later round
        // reuses the buffers reclaimed from the replaced community model.
        assert_eq!(allocs_per_round[0], tensor_count);
        assert_eq!(
            allocs_per_round.last(),
            allocs_per_round.first(),
            "steady-state rounds allocated output buffers: {allocs_per_round:?}"
        );
    }

    #[test]
    fn aggregate_result_is_shared_not_copied() {
        let ctrl = Controller::new(env(), None).unwrap();
        ctrl.ship_model(model(1));
        ctrl.open_round(1, &["a".into()]);
        ctrl.handle(Message::MarkTaskCompleted {
            task_id: 1,
            learner_id: "a".into(),
            model: ModelProto::from_model(&model(2), DType::F32, ByteOrder::Little),
            meta: TaskMeta { num_samples: 10, ..Default::default() },
        });
        let arrived = ctrl.wait_round_completions(Duration::from_secs(1));
        let new_model = ctrl.aggregate_from_store(&arrived, 1).unwrap();
        let (community, _) = ctrl.community().unwrap();
        // Same allocation: the slot and the return value alias one model.
        assert!(Arc::ptr_eq(&new_model, &community));
    }

    #[test]
    fn async_mix_discounts_stale_updates() {
        let e = FederationEnv::builder("async-test")
            .learners(2)
            .model(ModelSpec::mlp(4, 2, 8))
            .protocol(Protocol::Asynchronous { staleness_alpha: 1.0 })
            .build();
        let ctrl = Controller::new(e, None).unwrap();
        let base = model(1);
        ctrl.ship_model(base.clone());
        let update = model(2);
        let mp = ModelProto::from_model(&update, DType::F32, ByteOrder::Little);
        // Fresh update (staleness 0): w = 0.5.
        ctrl.handle(Message::MarkTaskCompleted {
            task_id: 1,
            learner_id: "a".into(),
            model: mp.clone(),
            meta: TaskMeta { num_samples: 100, ..Default::default() },
        });
        let (c1, r1) = ctrl.community().unwrap();
        assert_eq!(r1, 1);
        let expect = 0.5 * base.tensors[0].data[0] + 0.5 * update.tensors[0].data[0];
        assert!((c1.tensors[0].data[0] - expect).abs() < 1e-5);
        assert_eq!(ctrl.async_updates(), 1);
    }

    /// Drive a model through the streaming trio directly against
    /// `handle()` (no transport), via the REAL sender walk
    /// (`proto::client::stream_model_with`) so the test exercises the
    /// exact bytes/digest/seq the production client produces.
    fn stream_via_handle(
        ctrl: &Controller,
        purpose: StreamPurpose,
        task_id: u64,
        learner_id: &str,
        m: &TensorModel,
        meta: TaskMeta,
        chunk: usize,
    ) -> crate::proto::client::RpcResult<()> {
        crate::proto::client::stream_model_with(
            |msg| Ok(ctrl.handle(msg)),
            purpose,
            task_id,
            0,
            learner_id,
            m,
            &meta,
            chunk,
        )
    }

    #[test]
    fn streamed_round_is_bitwise_identical_to_one_shot() {
        // Same federation driven twice: learner uploads as one-shot
        // MarkTaskCompleted vs. as chunked streams (with a chunk size
        // that splits elements and tensors arbitrarily). The aggregated
        // community models must be bitwise identical.
        let one_shot = Controller::new(env(), None).unwrap();
        let streamed = Controller::new(env(), None).unwrap();
        one_shot.ship_model(model(1));
        streamed.ship_model(model(1));
        for ctrl in [&one_shot, &streamed] {
            ctrl.open_round(1, &["a".into(), "b".into()]);
        }
        for (i, id) in ["a", "b"].into_iter().enumerate() {
            let m = model(40 + i as u64);
            let meta = TaskMeta { num_samples: 10 + i, ..Default::default() };
            let reply = one_shot.handle(Message::MarkTaskCompleted {
                task_id: 1,
                learner_id: id.into(),
                model: ModelProto::from_model(&m, DType::F32, ByteOrder::Little),
                meta: meta.clone(),
            });
            assert!(matches!(reply, Message::Ack { ok: true, .. }), "{reply:?}");
            // 13-byte chunks: split mid-element and across tensor
            // boundaries on purpose (the unclamped sender walk makes
            // sub-MIN_CHUNK sizes reachable).
            stream_via_handle(&streamed, StreamPurpose::TaskCompletion, 1, id, &m, meta, 13)
                .unwrap();
        }
        for ctrl in [&one_shot, &streamed] {
            let arrived = ctrl.wait_round_completions(Duration::from_secs(1));
            assert_eq!(arrived.len(), 2);
            ctrl.aggregate_from_store(&arrived, 1).unwrap();
        }
        let (a, _) = one_shot.community().unwrap();
        let (b, _) = streamed.community().unwrap();
        assert_eq!(*a, *b, "streamed aggregation diverged from one-shot");
        assert_eq!(streamed.open_streams(), 0);
    }

    #[test]
    fn streamed_ship_model_installs_community() {
        let ctrl = Controller::new(env(), None).unwrap();
        let m = model(9);
        stream_via_handle(&ctrl, StreamPurpose::ShipModel, 0, "", &m, TaskMeta::default(), 32)
            .unwrap();
        let (community, _) = ctrl.community().unwrap();
        assert_eq!(*community, m);
    }

    #[test]
    fn stream_protocol_violations_are_typed_errors() {
        let ctrl = Controller::new(env(), None).unwrap();
        // Chunk/end for a stream that was never opened.
        for msg in [
            Message::ModelChunk { stream_id: 77, seq: 0, bytes: vec![0; 4] },
            Message::ModelStreamEnd { stream_id: 77, digest: 0 },
        ] {
            match ctrl.handle(msg) {
                Message::Error { code, .. } => assert_eq!(code, ErrorCode::StreamProtocol),
                other => panic!("unexpected {other:?}"),
            }
        }
        let m = model(3);
        let begin = |stream_id: u64| Message::ModelStreamBegin {
            stream_id,
            task_id: 1,
            round: 0,
            purpose: StreamPurpose::TaskCompletion,
            learner_id: "a".into(),
            layout: TensorLayoutProto::f32_layout_of(&m),
            meta: TaskMeta::default(),
        };
        // Duplicate stream id.
        assert!(matches!(ctrl.handle(begin(5)), Message::Ack { ok: true, .. }));
        match ctrl.handle(begin(5)) {
            Message::Error { code, .. } => assert_eq!(code, ErrorCode::StreamProtocol),
            other => panic!("unexpected {other:?}"),
        }
        // Out-of-order chunk kills the stream…
        match ctrl.handle(Message::ModelChunk { stream_id: 5, seq: 3, bytes: vec![0; 4] }) {
            Message::Error { code, .. } => assert_eq!(code, ErrorCode::StreamProtocol),
            other => panic!("unexpected {other:?}"),
        }
        // …so the follow-up end sees an unknown stream.
        assert!(matches!(
            ctrl.handle(Message::ModelStreamEnd { stream_id: 5, digest: 0 }),
            Message::Error { .. }
        ));
        assert_eq!(ctrl.open_streams(), 0);
        // Truncated stream: end before all bytes arrived.
        assert!(matches!(ctrl.handle(begin(6)), Message::Ack { ok: true, .. }));
        match ctrl.handle(Message::ModelStreamEnd { stream_id: 6, digest: FNV64_INIT }) {
            Message::Error { code, detail } => {
                assert_eq!(code, ErrorCode::StreamProtocol);
                assert!(detail.contains("truncated"), "{detail}");
            }
            other => panic!("unexpected {other:?}"),
        }
        // Digest mismatch.
        assert!(matches!(ctrl.handle(begin(8)), Message::Ack { ok: true, .. }));
        let mut seq = 0u64;
        for t in &m.tensors {
            let bytes = t.encode_data(DType::F32, ByteOrder::Little);
            ctrl.handle(Message::ModelChunk { stream_id: 8, seq, bytes });
            seq += 1;
        }
        match ctrl.handle(Message::ModelStreamEnd { stream_id: 8, digest: 0xBAD }) {
            Message::Error { code, detail } => {
                assert_eq!(code, ErrorCode::StreamProtocol);
                assert!(detail.contains("digest"), "{detail}");
            }
            other => panic!("unexpected {other:?}"),
        }
        // None of this touched round/community state.
        assert!(ctrl.community().is_none());
        assert_eq!(ctrl.open_streams(), 0);
    }

    #[test]
    fn one_shot_ingest_holds_whole_model_streamed_holds_chunks() {
        let m = model(2);
        let model_bytes = m.byte_size_f32();
        let one_shot = Controller::new(env(), None).unwrap();
        one_shot.ship_model(model(1));
        one_shot.handle(Message::MarkTaskCompleted {
            task_id: 1,
            learner_id: "a".into(),
            model: ModelProto::from_model(&m, DType::F32, ByteOrder::Little),
            meta: TaskMeta::default(),
        });
        assert!(one_shot.peak_wire_ingest_bytes() >= model_bytes);

        let streamed = Controller::new(env(), None).unwrap();
        streamed.ship_model(model(1));
        let chunk = 16;
        stream_via_handle(
            &streamed,
            StreamPurpose::TaskCompletion,
            1,
            "a",
            &m,
            TaskMeta::default(),
            chunk,
        )
        .unwrap();
        assert!(
            streamed.peak_wire_ingest_bytes() <= chunk,
            "streamed ingest held {} wire bytes for a {chunk}-byte chunk",
            streamed.peak_wire_ingest_bytes()
        );
    }

    #[test]
    fn hello_handshake_checks_version() {
        let ctrl = Controller::new(env(), None).unwrap();
        match ctrl.handle(Message::Hello { proto_version: PROTO_VERSION }) {
            Message::HelloAck { proto_version, component } => {
                assert_eq!(proto_version, PROTO_VERSION);
                assert_eq!(component, "controller");
            }
            other => panic!("unexpected {other:?}"),
        }
        match ctrl.handle(Message::Hello { proto_version: 999 }) {
            Message::Error { code, .. } => assert_eq!(code, ErrorCode::VersionMismatch),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn shutdown_rejects_further_messages() {
        let ctrl = Controller::new(env(), None).unwrap();
        assert_eq!(ctrl.handle(Message::Shutdown), Message::Ack { task_id: 0, ok: true });
        assert!(matches!(
            ctrl.handle(Message::GetModel),
            Message::Error { .. }
        ));
        assert!(ctrl.is_shutdown());
    }

    #[test]
    fn secure_over_tcp_rejected() {
        let mut e = env();
        e.secure = SecureSpec::Masking;
        e.transport = crate::config::TransportKind::Tcp { base_port: 45000 };
        assert!(Controller::new(e, None).is_err());
    }
}
