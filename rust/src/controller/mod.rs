//! The federation controller — "the first-class citizen of the system".
//!
//! Owns the community model, the learner registry, the model store, the
//! aggregation rule/backend, and the round lifecycle state. It is exposed
//! to the network as a [`Service`] handling the Appendix-B RPCs
//! (`Register`, `MarkTaskCompleted`, heartbeats, …); the round-driving
//! logic lives in [`scheduling`] (sync / semi-sync / async protocols),
//! fed by the per-learner performance profiles in [`pacing`].

pub mod aggregation;
mod bases;
pub mod health;
pub mod hierarchy;
pub mod pacing;
pub mod scheduling;
pub mod selector;
pub mod store;

use crate::config::{FederationEnv, Protocol, SecureSpec, SelectorSpec};
use crate::metrics::counters::{names, Counter, CounterRegistry};
use crate::metrics::{FedOp, OpMetrics};
use crate::net::chaos::{connect_with_chaos, ChaosPlan};
use crate::net::retry::RetryPolicy;
use crate::net::{ClientConn, Psk, Service};
use crate::obs::{SpanCtx, SpanSink};
use crate::proto::client::{self, StreamSend};
use crate::proto::ingest::{BufferPool, FinishedStream, IngestLimits, StreamBegin, StreamIngest};
use crate::proto::wire::{fnv1a64, FNV64_INIT};
use crate::runtime::trace::TraceRecorder;
use crate::proto::{
    ErrorCode, HealthProbe, Message, ModelProto, StreamPurpose, TaskMeta, TaskSpec,
    TensorLayoutProto, PROTO_VERSION,
};
use crate::tensor::{ByteOrder, CodecId, DType, TensorModel};
use crate::util::clock::{Clock, Timestamp};
use crate::util::{log_debug, log_info, Rng, Stopwatch, ThreadPool};
use aggregation::{Backend, Contribution, ScratchArena};
use anyhow::{bail, Context, Result};
use bases::BaseMap;
use pacing::PacingRegistry;
use selector::{SelectionCtx, Selector};
use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;
use store::{ModelStore, StoredModel};

/// A registered learner as seen by the controller.
pub struct LearnerHandle {
    pub id: String,
    pub endpoint: String,
    pub num_samples: usize,
    pub index: usize,
    conn: Mutex<Option<Box<dyn ClientConn>>>,
    /// Codec set the learner accepted in this connection's `Hello`
    /// handshake (`None` until a connection has been established). The
    /// fan-out path intersects these across targets so a mixed fleet
    /// degrades the dispatch codec instead of erroring at `Begin`.
    accepted: Mutex<Option<Vec<CodecId>>>,
    /// Fault-injection plan for the *dispatch* direction (chaos
    /// harness). When set, every (re)dial of this handle's connection
    /// routes through the chaos transport — the same plan the learner's
    /// callback side wraps, so a severed link kills both directions of
    /// the conversation, not just the upload half.
    chaos: Mutex<Option<ChaosPlan>>,
    /// Clock that paces this handle's dials, chaos stalls, and dispatch
    /// timing samples. Handles registered through a controller inherit
    /// its clock, so sim fleets measure RPC time in virtual time.
    clock: Clock,
}

impl LearnerHandle {
    pub fn new(id: String, endpoint: String, num_samples: usize, index: usize) -> LearnerHandle {
        Self::with_clock(id, endpoint, num_samples, index, Clock::system())
    }

    pub fn with_clock(
        id: String,
        endpoint: String,
        num_samples: usize,
        index: usize,
        clock: Clock,
    ) -> LearnerHandle {
        LearnerHandle {
            id,
            endpoint,
            num_samples,
            index,
            conn: Mutex::new(None),
            accepted: Mutex::new(None),
            chaos: Mutex::new(None),
            clock,
        }
    }

    /// Dial + handshake if no connection is cached. Every dispatch
    /// connection opens with the versioned `Hello`, so the codec set the
    /// peer speaks is known before any stream `Begin`. Peers that answer
    /// `Hello` with an application error (legacy builds, test doubles)
    /// are recorded as f32-only rather than treated as unreachable.
    fn ensure_conn(&self, guard: &mut Option<Box<dyn ClientConn>>, psk: Psk) -> Result<()> {
        if guard.is_some() {
            return Ok(());
        }
        let plan = self.chaos.lock().unwrap().clone();
        let mut conn = match &plan {
            Some(p) => connect_with_chaos(&self.endpoint, psk, p, &self.clock),
            None => crate::net::connect(&self.endpoint, psk),
        }
        .with_context(|| format!("connecting to learner {}", self.id))?;
        let accepted = match client::hello_negotiate(conn.as_mut()) {
            Ok((_version, codecs)) => codecs,
            Err(e) if e.is_transport() => {
                return Err(anyhow::anyhow!("handshake with learner {}: {e}", self.id));
            }
            Err(e) => {
                log_debug(
                    "controller",
                    &format!("{}: Hello refused ({e}); assuming f32-only peer", self.id),
                );
                vec![CodecId::F32]
            }
        };
        *self.accepted.lock().unwrap() = Some(accepted);
        *guard = Some(conn);
        Ok(())
    }

    /// Codec set this learner accepted, handshaking first if this handle
    /// has never connected. `None` when the learner is unreachable (the
    /// dispatch itself will surface that error).
    pub fn accepted_codecs(&self, psk: Psk) -> Option<Vec<CodecId>> {
        {
            let mut guard = self.conn.lock().unwrap();
            if self.ensure_conn(&mut guard, psk).is_err() {
                return None;
            }
        }
        self.accepted.lock().unwrap().clone()
    }

    /// RPC to this learner, (re)connecting lazily. The per-learner lock
    /// serializes concurrent calls onto one connection.
    pub fn rpc(&self, psk: Psk, msg: &Message) -> Result<Message> {
        let origin = self.clock.now();
        self.rpc_timed(psk, msg, origin).map(|(m, _)| m)
    }

    /// RPC that also reports *when* (relative to `origin`, a stamp taken
    /// on this handle's clock) the send (dispatch) phase finished,
    /// separate from the reply wait.
    pub fn rpc_timed(
        &self,
        psk: Psk,
        msg: &Message,
        origin: Timestamp,
    ) -> Result<(Message, Duration)> {
        self.rpc_inner(psk, RawOrMsg::Msg(msg), origin)
    }

    /// RPC with pre-encoded request bytes (broadcast fast path: the bytes
    /// are shared across all learners of a round — §Perf).
    pub fn rpc_raw_timed(
        &self,
        psk: Psk,
        bytes: &[u8],
        origin: Timestamp,
    ) -> Result<(Message, Duration)> {
        self.rpc_inner(psk, RawOrMsg::Raw(bytes), origin)
    }

    fn rpc_inner(
        &self,
        psk: Psk,
        req: RawOrMsg<'_>,
        origin: Timestamp,
    ) -> Result<(Message, Duration)> {
        let mut guard = self.conn.lock().unwrap();
        self.ensure_conn(&mut guard, psk)?;
        let conn = guard.as_mut().unwrap();
        let send_res = match req {
            RawOrMsg::Msg(m) => conn.send(m),
            RawOrMsg::Raw(b) => conn.send_raw(b),
        };
        let sent_at = self.clock.since(origin);
        let result = send_res.and_then(|_| conn.recv());
        match result {
            Ok(reply) => Ok((reply, sent_at)),
            Err(e) => {
                *guard = None; // force reconnect next time
                Err(e)
            }
        }
    }
}

enum RawOrMsg<'a> {
    Msg(&'a Message),
    Raw(&'a [u8]),
}

/// Completion record delivered by `MarkTaskCompleted`.
struct RoundState {
    round: u64,
    expecting: HashSet<String>,
    arrived: Vec<String>,
    /// When the round's tasks were dispatched, on the controller clock
    /// (arrival offsets below are measured from here).
    opened_at: Timestamp,
    /// Offsets of the first and latest in-round completion — their
    /// difference is the round's straggler spread, the quantity
    /// pacing-aware semi-sync exists to shrink.
    first_arrival: Option<Duration>,
    last_arrival: Option<Duration>,
}

/// What a round barrier wait observed (see
/// [`Controller::wait_round_quorum`]).
pub(crate) struct RoundOutcome {
    /// Learners that completed in time, sorted by id.
    pub arrived: Vec<String>,
    /// Expected learners that had not completed when the round closed
    /// (timeout or quorum cut) — pacing failure accounting feeds on
    /// these.
    pub missing: Vec<String>,
    /// Wall clock between the first and the last counted completion.
    pub completion_spread: Duration,
}

struct CtrlState {
    /// Community model, shared by pointer: schedulers snapshot it, the
    /// store hands back `Arc`s, and aggregation reads through them — the
    /// controller never deep-copies a model on the hot path.
    community: Option<Arc<TensorModel>>,
    community_round: u64,
    rule: Box<dyn aggregation::AggregationRule>,
    store: Box<dyn ModelStore>,
    learners: Vec<Arc<LearnerHandle>>,
    last_participation: HashMap<String, u64>,
    /// Round each learner's current task was dispatched at (staleness).
    dispatch_round: HashMap<String, u64>,
    /// When each learner's current task was handed out (controller
    /// clock) — consumed by the completion path as the task RTT sample
    /// for its profile.
    task_sent_at: HashMap<String, Timestamp>,
    /// Highest task id each learner's completion has been *accepted*
    /// for (round arrival or late fold). Makes the late-fold path
    /// idempotent: a duplicate / replayed `MarkTaskCompleted` (lost
    /// ack + reconnect) must not re-mix a model that was already
    /// counted.
    completed_tasks: HashMap<String, u64>,
    round: Option<RoundState>,
    /// Async protocol: community updates applied so far.
    async_updates: u64,
    /// Async protocol: learners with a task currently in flight.
    outstanding: HashSet<String>,
}

/// Injected XLA aggregation kernel (compiled via the runtime module).
pub use aggregation::XlaAggFn;

/// The federation controller.
pub struct Controller {
    pub env: FederationEnv,
    pub psk: Psk,
    /// Time source for every controller-side stamp, wait, and sleep:
    /// round open/arrival offsets, quorum deadlines, dispatch timing,
    /// retry backoff, and the ingest GC all read this one handle.
    /// `Clock::system()` for real fleets, `Clock::sim()` for simulated
    /// and replayed runs.
    clock: Clock,
    /// Degradation/wire counter registry shared with the ingest engine
    /// (and snapshotted whole into `FederationReport` / traces).
    counters: Arc<CounterRegistry>,
    backend: Backend,
    state: Mutex<CtrlState>,
    round_cv: Condvar,
    metrics: Mutex<OpMetrics>,
    dispatch_pool: ThreadPool,
    shutdown: AtomicBool,
    xla_slot: Mutex<Option<XlaAggFn>>,
    /// Inbound data-plane engine (upload streams). Deliberately
    /// *outside* the `CtrlState` mutex: chunk ingest for one learner
    /// never contends with the round barrier or another learner's
    /// stream. Also owns the wire-memory gauge shared with the one-shot
    /// decode paths.
    ingest: StreamIngest,
    /// Identity + pointer of the community model most recently fanned
    /// out over a lossless streamed dispatch — the shared base the next
    /// delta-coded dispatch encodes against. Only populated when the
    /// env's wire codec resolves to delta, so it never pins buffers the
    /// arena could otherwise recycle.
    last_broadcast: Mutex<Option<(u64, Arc<TensorModel>)>>,
    /// Per-learner identity + pointer of the last model each learner
    /// acknowledged over a lossless dispatch stream. The async protocol
    /// re-dispatches per learner at divergent community rounds, so a
    /// single shared base cannot serve it; the upload plane also
    /// resolves delta bases here when the community model has already
    /// moved past the round a learner trained on. LRU-capped on
    /// distinct pinned models (see [`bases::BaseMap`]): evicted
    /// learners degrade to full-f32 sends, and deregistration drops a
    /// learner's entry.
    learner_bases: Mutex<BaseMap>,
    /// Per-learner performance profiles (EWMA throughput / RTT,
    /// completion & failure history) — the measurement substrate for
    /// pacing-aware semi-sync budgets, quorum failure accounting, and
    /// `Selector::PacingAware`.
    pacing: PacingRegistry,
    /// Completions that arrived after their round closed and were
    /// folded into the community model through the async staleness path
    /// (deadline-quorum rounds) instead of being dropped.
    late_folds: Counter,
    /// Codec `encode` invocations performed by streamed dispatch — the
    /// encode-once probe: fanning one model out to N learners must cost
    /// one encode per payload unit (tensor, or frame for framed codecs),
    /// not `N ×` that (asserted in tests/streaming.rs).
    dispatch_encodes: Counter,
    /// Data-plane egress totals: payload bytes actually sent by streamed
    /// dispatch, and their f32-equivalent volume. Together with the
    /// ingest's receive totals these become the `FederationReport`
    /// `wire_bytes_sent` / `wire_bytes_saved` gauges.
    dispatch_wire_sent: Counter,
    dispatch_wire_raw: Counter,
    /// Single-target dispatches abandoned after the unified retry policy
    /// exhausted its attempts (transport faults only — application
    /// errors never retry). Surfaced in `FederationReport`.
    retry_give_ups: Counter,
    /// Delta→f32 fallback re-sends: streams restarted at full precision
    /// because the learner no longer held the negotiated delta base.
    fallback_sends: Counter,
    /// Deterministic-trace recorder (see [`crate::runtime::trace`]).
    /// Lock hierarchy: `recorder` is taken *before* `state` /
    /// `learner_bases`, and held across each recorded event plus the
    /// state mutation it describes, so the trace order is the
    /// controller's serialized timeline. `None` unless a recording is
    /// active.
    recorder: Mutex<Option<TraceRecorder>>,
    /// Fast-path gate so non-recording runs never touch the recorder
    /// mutex (set by `start_recording`, cleared by `finish_recording`).
    recording: AtomicBool,
    /// Span recorder for controller-side operations — round brackets,
    /// dispatch fan-outs, ingest, aggregation, late folds (see
    /// [`crate::obs::span`]). Disabled by default; the driver enables
    /// it when the env's `observability.spans` flag is set.
    spans: Arc<SpanSink>,
    /// Trace context inherited from upstream: a
    /// [`hierarchy::AggregatorNode`] parents its embedded controller's
    /// work under the root dispatch span that caused it. Unset on a
    /// root controller, where rounds root fresh traces.
    span_parent: Mutex<SpanCtx>,
    /// Context of the current round's root span (installed by the
    /// scheduler / shard-round driver for the round's duration).
    /// Dispatch and aggregation spans parent under it, falling back to
    /// `span_parent` between rounds.
    round_ctx: Mutex<SpanCtx>,
}

impl Controller {
    pub fn new(env: FederationEnv, psk: Psk) -> Result<Arc<Controller>> {
        Self::with_clock(env, psk, Clock::system())
    }

    /// Construct against an explicit time source. `Clock::sim()` runs
    /// the whole control plane — pacing stamps, quorum deadlines, retry
    /// backoff, ingest GC — in discrete virtual time (`loadtest --sim`,
    /// trace replay).
    pub fn with_clock(env: FederationEnv, psk: Psk, clock: Clock) -> Result<Arc<Controller>> {
        env.validate()?;
        if env.secure != SecureSpec::None && !matches!(env.transport, crate::config::TransportKind::InProc) {
            bail!("secure aggregation is only wired for in-process simulation (see DESIGN.md)");
        }
        let backend = Backend::from_spec(&env.aggregation);
        let rule = aggregation::rule_from_spec(&env.aggregation)?;
        let dispatch_threads = env.learners.clamp(1, 16);
        let counters = CounterRegistry::new();
        // Keyed by env name so a two-tier run's shard controllers
        // (shard_env renames them "<root>/<agg-id>") allocate span ids
        // under distinct prefixes — ids must stay unique across every
        // sink that can feed one trace.
        let spans = SpanSink::new(format!("controller/{}", env.name), clock.clone());
        Ok(Arc::new(Controller {
            backend,
            state: Mutex::new(CtrlState {
                community: None,
                community_round: 0,
                rule,
                store: Box::new(store::InMemoryStore::new()),
                learners: Vec::new(),
                last_participation: HashMap::new(),
                dispatch_round: HashMap::new(),
                task_sent_at: HashMap::new(),
                completed_tasks: HashMap::new(),
                round: None,
                async_updates: 0,
                outstanding: HashSet::new(),
            }),
            round_cv: Condvar::new(),
            metrics: Mutex::new(OpMetrics::new()),
            dispatch_pool: ThreadPool::with_clock(dispatch_threads, clock.clone()),
            shutdown: AtomicBool::new(false),
            xla_slot: Mutex::new(None),
            ingest: StreamIngest::with_clock(
                IngestLimits::default(),
                clock.clone(),
                Arc::clone(&counters),
            ),
            last_broadcast: Mutex::new(None),
            learner_bases: Mutex::new(BaseMap::new(bases::DEFAULT_BASE_MODEL_CAP)),
            pacing: PacingRegistry::default(),
            late_folds: counters.counter(names::LATE_FOLDS),
            dispatch_encodes: counters.counter(names::DISPATCH_ENCODES),
            dispatch_wire_sent: counters.counter(names::DISPATCH_WIRE_SENT),
            dispatch_wire_raw: counters.counter(names::DISPATCH_WIRE_RAW),
            retry_give_ups: counters.counter(names::RETRY_GIVE_UPS),
            fallback_sends: counters.counter(names::FALLBACK_SENDS),
            recorder: Mutex::new(None),
            recording: AtomicBool::new(false),
            spans,
            span_parent: Mutex::new(SpanCtx::UNSET),
            round_ctx: Mutex::new(SpanCtx::UNSET),
            env,
            psk,
            clock,
            counters,
        }))
    }

    /// The inbound data-plane engine (it runs on the controller's
    /// clock; gauges for ops dashboards).
    pub fn ingest(&self) -> &StreamIngest {
        &self.ingest
    }

    /// The learner pacing registry (per-learner performance profiles).
    pub fn pacing(&self) -> &PacingRegistry {
        &self.pacing
    }

    /// The controller's time source (shared by its ingest engine,
    /// dispatch pool, and every registered learner handle).
    pub fn clock(&self) -> &Clock {
        &self.clock
    }

    /// The degradation/wire counter registry. `snapshot()` gives every
    /// counter in one call — the `FederationReport` and trace footer
    /// read it whole instead of polling accessors one by one.
    pub fn counters(&self) -> &Arc<CounterRegistry> {
        &self.counters
    }

    /// This controller's span sink. Spans record only after `enable()`;
    /// a disabled sink costs one atomic load per would-be span (see
    /// [`crate::obs::SpanSink`]).
    pub fn span_sink(&self) -> &Arc<SpanSink> {
        &self.spans
    }

    /// Parent future controller-side spans (round roots included)
    /// under `ctx` — the hierarchy tier joins shard work to the root's
    /// federation-wide trace through this seam.
    pub(crate) fn set_span_parent(&self, ctx: SpanCtx) {
        *self.span_parent.lock().unwrap() = ctx;
    }

    pub(crate) fn span_parent(&self) -> SpanCtx {
        *self.span_parent.lock().unwrap()
    }

    /// Install / clear the current round root span's context (held for
    /// the scheduler's round scope).
    pub(crate) fn set_round_ctx(&self, ctx: SpanCtx) {
        *self.round_ctx.lock().unwrap() = ctx;
    }

    /// Context controller-side work spans parent under: the open
    /// round's root span when one is active, else the inherited
    /// upstream context.
    pub(crate) fn work_ctx(&self) -> SpanCtx {
        let ctx = *self.round_ctx.lock().unwrap();
        if ctx.is_set() {
            ctx
        } else {
            self.span_parent()
        }
    }

    /// Completions folded through the async staleness path because they
    /// arrived after their deadline-quorum round had closed.
    pub fn late_folds(&self) -> u64 {
        self.late_folds.get()
    }

    /// Single-target dispatches abandoned after retry exhaustion.
    pub fn retry_give_ups(&self) -> u64 {
        self.retry_give_ups.get()
    }

    /// Delta→f32 fallback re-sends across both dispatch paths.
    pub fn fallback_sends(&self) -> u64 {
        self.fallback_sends.get()
    }

    /// Real component state for `HeartbeatAck`: whether a round is
    /// open, how many ingest streams are live (wedged streams show up
    /// here until the GC reclaims them), and how many dispatches were
    /// abandoned after retry exhaustion. The ack's `healthy` flag is
    /// [`HealthProbe::is_healthy`] over this snapshot.
    pub fn health_probe(&self) -> HealthProbe {
        HealthProbe {
            open_rounds: u64::from(self.state.lock().unwrap().round.is_some()),
            open_streams: self.open_streams() as u64,
            retry_give_ups: self.retry_give_ups(),
        }
    }

    /// Override the LRU cap on distinct pinned delta-base models
    /// (tests; ops tuning for very large async fleets).
    pub fn set_learner_base_cap(&self, cap_models: usize) {
        *self.learner_bases.lock().unwrap() = BaseMap::new(cap_models);
    }

    /// Replace the model store (e.g. [`store::OnDiskStore`]).
    pub fn set_store(&self, s: Box<dyn ModelStore>) {
        self.state.lock().unwrap().store = s;
    }

    /// Wire the XLA aggregation backend (injected by `runtime` after the
    /// compiled fedavg kernel is loaded; until then the Xla config choice
    /// falls back to Sequential).
    pub fn set_xla_backend(&self, f: XlaAggFn) {
        *self.xla_slot.lock().unwrap() = Some(f);
    }

    /// Effective backend for aggregation (resolves the Xla slot).
    fn effective_backend(&self) -> Backend {
        if self.env.aggregation.backend == crate::config::AggregationBackend::Xla {
            if let Some(f) = self.xla_slot.lock().unwrap().clone() {
                return Backend::Xla(f);
            }
        }
        self.backend.clone()
    }

    pub fn is_shutdown(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst)
    }

    /// Registered learner count.
    pub fn learner_count(&self) -> usize {
        self.state.lock().unwrap().learners.len()
    }

    /// Wait until `n` learners registered (driver startup barrier).
    pub fn wait_for_learners(&self, n: usize, timeout: Duration) -> Result<()> {
        let deadline = self.clock.now() + timeout;
        let mut state = self.state.lock().unwrap();
        while state.learners.len() < n {
            let remaining = deadline
                .checked_sub(self.clock.now())
                .filter(|d| !d.is_zero())
                .ok_or_else(|| anyhow::anyhow!("timeout waiting for {n} learners"))?;
            let (s, _) = self.clock.wait_timeout(&self.round_cv, state, remaining);
            state = s;
        }
        Ok(())
    }

    /// Snapshot of the community model (initialized by `ShipModel`).
    /// Returns a shared pointer — no copy. Callers that keep the snapshot
    /// across an aggregation (schedulers) should drop it once serialized
    /// so the controller can recycle the buffers on replacement.
    pub fn community(&self) -> Option<(Arc<TensorModel>, u64)> {
        let s = self.state.lock().unwrap();
        s.community.clone().map(|m| (m, s.community_round))
    }

    /// Set the community model directly (driver-local initialization).
    /// When recording, the install is captured as a synthetic inbound
    /// `ShipModel` frame so a replay seeds the identical model.
    pub fn ship_model(&self, model: TensorModel) {
        let _rec = self.trace(|r, tick| {
            let msg = Message::ShipModel {
                model: ModelProto::from_model(&model, DType::F32, ByteOrder::Little),
            };
            r.inbound(tick, &msg.encode());
        });
        self.install_model(model);
    }

    /// `ship_model` minus the trace hook — the `ShipModel` RPC arm lands
    /// here (its frame was already recorded by the `handle` wrapper,
    /// which still holds the recorder lock).
    fn install_model(&self, model: TensorModel) {
        let mut s = self.state.lock().unwrap();
        s.community = Some(Arc::new(model));
        log_info("controller", "community model initialized");
    }

    /// Register a learner directly (in-proc driver path).
    pub fn register_learner(&self, id: &str, endpoint: &str, num_samples: usize) -> usize {
        let mut s = self.state.lock().unwrap();
        let index = s.learners.len();
        s.learners.push(Arc::new(LearnerHandle::with_clock(
            id.to_string(),
            endpoint.to_string(),
            num_samples,
            index,
            self.clock.clone(),
        )));
        log_debug("controller", &format!("registered learner {id} at {endpoint} (#{index})"));
        self.round_cv.notify_all();
        index
    }

    /// Deregister a learner: drop its handle and every per-learner map
    /// entry — participation history, staleness bookkeeping, pacing
    /// profile, and its pinned delta base (whose buffers go back to the
    /// arena when nothing else holds them).
    pub fn deregister_learner(&self, id: &str) -> bool {
        let found = {
            let mut s = self.state.lock().unwrap();
            let before = s.learners.len();
            s.learners.retain(|l| l.id != id);
            let found = s.learners.len() != before;
            s.last_participation.remove(id);
            s.dispatch_round.remove(id);
            s.task_sent_at.remove(id);
            s.completed_tasks.remove(id);
            s.outstanding.remove(id);
            // Don't leave an open round waiting on the departed
            // learner: drop it from `expecting` (unless its completion
            // already arrived — that model is stored and stays
            // aggregatable), so the barrier re-targets without it and
            // it is never reported "missing" (which would resurrect
            // the pacing profile as a failure ghost).
            if let Some(r) = s.round.as_mut() {
                if !r.arrived.iter().any(|a| a == id) {
                    r.expecting.remove(id);
                }
            }
            found
        };
        self.pacing.remove(id);
        if let Some(base) = self.learner_bases.lock().unwrap().remove(id) {
            if let Some(scratch) = self.effective_backend().scratch() {
                scratch.reclaim_model(base);
            }
        }
        if found {
            log_debug("controller", &format!("deregistered learner {id}"));
        }
        // Wake the round barrier: its quorum target just shrank.
        self.round_cv.notify_all();
        found
    }

    fn learners_snapshot(&self) -> Vec<Arc<LearnerHandle>> {
        self.state.lock().unwrap().learners.clone()
    }

    /// Route every future dispatch dial to `learner_id` through a
    /// fault-injection plan (chaos harness) — the controller→learner
    /// mirror of [`crate::learner::Learner::set_chaos`]. The cached
    /// connection, if any, is dropped so the plan takes effect on the
    /// next call. Returns false when the learner is not registered.
    pub fn set_dispatch_chaos(&self, learner_id: &str, plan: ChaosPlan) -> bool {
        let handle = self
            .learners_snapshot()
            .into_iter()
            .find(|h| h.id == learner_id);
        match handle {
            Some(h) => {
                *h.chaos.lock().unwrap() = Some(plan);
                *h.conn.lock().unwrap() = None;
                true
            }
            None => false,
        }
    }

    /// Metrics snapshot.
    pub fn metrics(&self) -> OpMetrics {
        self.metrics.lock().unwrap().clone()
    }

    pub(crate) fn record(&self, op: FedOp, d: Duration) {
        self.metrics.lock().unwrap().record(op, d);
    }

    // ---- deterministic trace record/replay ---------------------------

    /// Run `f` against the trace recorder if a recording is active and
    /// return the held guard, so the caller can extend the recorder
    /// critical section across the state mutation the event describes
    /// (trace order == live order == replay order).
    fn trace<F>(&self, f: F) -> Option<std::sync::MutexGuard<'_, Option<TraceRecorder>>>
    where
        F: FnOnce(&mut TraceRecorder, Timestamp),
    {
        if !self.recording.load(Ordering::Acquire) {
            return None;
        }
        let mut g = self.recorder.lock().unwrap();
        let tick = self.clock.now();
        match g.as_mut() {
            Some(r) => f(r, tick),
            None => return None,
        }
        Some(g)
    }

    /// Start recording a deterministic trace of every state-bearing
    /// event: raw inbound frames plus scheduler decisions (round
    /// open/close, aggregation, async marks, delta-base pins). The
    /// trace embeds this controller's environment so `metisfl replay`
    /// can rebuild an identical one.
    pub fn start_recording(&self) {
        let mut g = self.recorder.lock().unwrap();
        *g = Some(TraceRecorder::new(&self.env.to_yaml_source()));
        drop(g);
        self.recording.store(true, Ordering::Release);
        log_info("controller", "trace recording started");
    }

    /// Seal and return the active recording (`None` if none). The
    /// footer captures the community digest and counter snapshot *as of
    /// the last recorded event*: the recorder lock is taken first, so
    /// every frame in the trace has fully applied, and any frame still
    /// waiting on the lock seals out — absent from both the timeline
    /// and the footer.
    pub fn finish_recording(&self) -> Option<Vec<u8>> {
        let mut g = self.recorder.lock().unwrap();
        let mut rec = g.take();
        // Spans recorded so far ride the trace as one observability
        // batch (replay ignores it; `trace dump` renders it).
        if let Some(r) = rec.as_mut() {
            let spans = self.spans.drain();
            r.spans(self.clock.now(), &spans);
        }
        let rec = rec;
        let digest = self
            .community()
            .map(|(m, _)| crate::runtime::trace::model_digest(&m))
            .unwrap_or(0);
        let counters = self.counters.snapshot();
        self.recording.store(false, Ordering::Release);
        drop(g);
        let rec = rec?;
        log_info(
            "controller",
            &format!("trace recording finished ({} events)", rec.events()),
        );
        Some(rec.finish(digest, &counters))
    }

    /// Replay shims (see [`crate::runtime::trace::replay`]): thin
    /// entries over the same internals the live schedulers drive.
    pub(crate) fn replay_open_round(&self, round: u64, expecting: &[String]) {
        self.open_round(round, expecting);
    }

    /// Close the open round exactly where the recording closed it:
    /// zero timeout — whoever has arrived by this point in the event
    /// order is the cut.
    pub(crate) fn replay_close_round(&self) -> Vec<String> {
        self.wait_round_quorum(Duration::ZERO, 1.0).arrived
    }

    pub(crate) fn replay_aggregate(&self, ids: &[String], round: u64) -> Result<()> {
        self.aggregate_from_store(ids, round)?;
        Ok(())
    }

    pub(crate) fn replay_mark_outstanding(&self, id: &str) {
        self.mark_task_outstanding(id);
    }

    /// Re-install a recorded delta-base pin (`model` is the replay's
    /// own community snapshot at `round` — the same model the live
    /// dispatch pinned, rebuilt from the same events).
    pub(crate) fn replay_set_base(&self, id: &str, round: u64, model: Arc<TensorModel>) {
        let displaced = self.learner_bases.lock().unwrap().insert(id, round, model);
        drop(displaced);
    }

    // ---- round plumbing used by `scheduling` -------------------------

    /// Open a round: note who we expect and stamp dispatch rounds +
    /// task send times (the completion path turns the latter into RTT
    /// profile samples).
    fn open_round(&self, round: u64, expecting: &[String]) {
        let _rec = self.trace(|r, tick| r.round_open(tick, round, expecting));
        crate::util::logging::set_round(round);
        let now = self.clock.now();
        let mut s = self.state.lock().unwrap();
        for id in expecting {
            s.dispatch_round.insert(id.clone(), round);
            s.last_participation.insert(id.clone(), round);
            s.task_sent_at.insert(id.clone(), now);
        }
        s.round = Some(RoundState {
            round,
            expecting: expecting.iter().cloned().collect(),
            arrived: Vec::new(),
            opened_at: now,
            first_arrival: None,
            last_arrival: None,
        });
    }

    /// Block until all expected completions arrived or `timeout`
    /// elapsed. Returns the learner ids that did arrive.
    #[cfg(test)]
    fn wait_round_completions(&self, timeout: Duration) -> Vec<String> {
        self.wait_round_quorum(timeout, 1.0).arrived
    }

    /// Block until a quorum of the expected completions arrived or
    /// `timeout` elapsed, then close the round. `quorum` is the
    /// fraction of expected learners that must complete (1.0 = the
    /// classic all-or-timeout barrier); the target is at least one.
    /// Completions landing after the close are "late" — under
    /// `quorum_fraction < 1` they fold through the async staleness path
    /// (see [`Controller::complete_task`]).
    fn wait_round_quorum(&self, timeout: Duration, quorum: f64) -> RoundOutcome {
        let deadline = self.clock.now() + timeout;
        let mut s = self.state.lock().unwrap();
        loop {
            let done = match &s.round {
                // Deregistration can empty `expecting` mid-round;
                // nothing left to wait for.
                Some(r) if r.expecting.is_empty() => true,
                Some(r) => {
                    let target = ((r.expecting.len() as f64 * quorum).ceil() as usize)
                        .clamp(1, r.expecting.len());
                    r.arrived.len() >= target
                }
                None => true,
            };
            if done {
                break;
            }
            let Some(remaining) =
                deadline.checked_sub(self.clock.now()).filter(|d| !d.is_zero())
            else {
                break;
            };
            let (guard, _) = self.clock.wait_timeout(&self.round_cv, s, remaining);
            s = guard;
        }
        // Close under the recorder lock (recorder → state order): drop
        // the wait loop's state guard, take the recorder, re-lock state
        // and take the round. A completion landing in the gap is either
        // recorded before the close (and is in `arrived`) or after it
        // (and late-folds) — consistent in both timelines.
        drop(s);
        let rec = if self.recording.load(Ordering::Acquire) {
            Some(self.recorder.lock().unwrap())
        } else {
            None
        };
        let mut s = self.state.lock().unwrap();
        let closing_round = s.round.as_ref().map(|r| r.round);
        let (mut arrived, mut missing, completion_spread) = match s.round.take() {
            Some(r) => {
                let spread = match (r.first_arrival, r.last_arrival) {
                    (Some(first), Some(last)) => last.saturating_sub(first),
                    _ => Duration::ZERO,
                };
                let arrived_set: HashSet<&String> = r.arrived.iter().collect();
                let missing = r
                    .expecting
                    .iter()
                    .filter(|id| !arrived_set.contains(id))
                    .cloned()
                    .collect();
                (r.arrived, missing, spread)
            }
            None => (Vec::new(), Vec::new(), Duration::ZERO),
        };
        // Sort so aggregation order (and thus fp rounding) is independent
        // of completion timing — parallel and sequential runs of the same
        // federation produce bitwise-identical community models.
        arrived.sort();
        missing.sort();
        drop(s);
        if let (Some(mut g), Some(round)) = (rec, closing_round) {
            if let Some(r) = g.as_mut() {
                r.round_close(self.clock.now(), round, &arrived);
            }
        }
        crate::util::logging::clear_round();
        RoundOutcome { arrived, missing, completion_spread }
    }

    /// Aggregate `learner_ids`' latest stored models into a new community
    /// model (T4–T7). Returns the new model (shared, not copied).
    ///
    /// Hot-path properties: `current` and every selection from the store
    /// are `Arc` clones — no model is deep-copied — and with the chunked
    /// backend the output is written into recycled scratch buffers, so a
    /// steady-state round performs zero O(params) allocation.
    fn aggregate_from_store(&self, learner_ids: &[String], round: u64) -> Result<Arc<TensorModel>> {
        let _rec = self.trace(|r, tick| r.aggregate(tick, round, learner_ids));
        let _span = self.spans.begin("aggregate", self.work_ctx()).round(round);
        let backend = self.effective_backend();
        let mut s = self.state.lock().unwrap();
        let current = s
            .community
            .clone()
            .ok_or_else(|| anyhow::anyhow!("no community model shipped"))?;
        let selected = s.store.select_latest(learner_ids)?;
        if selected.is_empty() {
            bail!("round {round}: no completed learner models to aggregate");
        }
        let contributions: Vec<Contribution> = selected
            .iter()
            .map(|m| Contribution {
                model: Arc::clone(&m.model),
                weight: m.meta.num_samples.max(1) as f64,
            })
            .collect();
        let new_model = Arc::new(s.rule.aggregate(&current, &contributions, &backend)?);
        let previous = s.community.replace(Arc::clone(&new_model));
        s.community_round = round;
        // Keep only the freshest model per learner (paper's in-memory
        // assumption; lineage stores are opt-in via set_store + evict).
        let evicted = s.store.evict(1)?;
        drop(s);
        // Release our handles on the models leaving circulation — the
        // replaced community model and this round's other aggregation
        // inputs — then hand every uniquely-owned buffer back to the
        // arena: the replaced community model AND the store-evicted
        // contributions (last round's uploads, just superseded). A
        // steady-state streamed round draws its ingest buffers and its
        // aggregation output entirely from this pool, allocating
        // nothing (asserted in tests/streaming.rs).
        drop(contributions);
        drop(selected);
        drop(current);
        if let Some(scratch) = backend.scratch() {
            if let Some(prev) = previous {
                scratch.reclaim_model(prev);
            }
            for entry in evicted {
                scratch.reclaim_model(entry.model);
            }
        }
        if crate::util::logging::enabled(crate::util::logging::LogLevel::Debug) {
            log_debug(
                "controller",
                &format!(
                    "round {round}: community ‖w‖₂ = {:.6}",
                    aggregation::model_l2_norm(&new_model, &backend)
                ),
            );
        }
        Ok(new_model)
    }

    /// Async protocol: mix one completed local model into the community
    /// model immediately, discounted by staleness (Stripelis 2022b).
    fn async_mix(&self, entry: &StoredModel, alpha: f64) -> Result<u64> {
        self.mix_completion(entry, alpha, true, None)
    }

    /// Staleness-discounted mix of one completed model into the
    /// community model — the async protocol's update step, also reused
    /// by deadline-quorum rounds to fold *late* completions instead of
    /// dropping them. `async_update` distinguishes the two: the async
    /// protocol advances the community round and its scheduler
    /// bookkeeping; a late fold only blends the model (the sync
    /// schedulers own the round counter). `trained_round` overrides the
    /// staleness basis with the round the model was actually trained
    /// for (late folds pass the completion's task id — the learner's
    /// `dispatch_round` entry may already point at a NEWER task,
    /// because re-selection overwrites it).
    fn mix_completion(
        &self,
        entry: &StoredModel,
        alpha: f64,
        async_update: bool,
        trained_round: Option<u64>,
    ) -> Result<u64> {
        let backend = self.effective_backend();
        let mut s = self.state.lock().unwrap();
        let current = s
            .community
            .clone()
            .ok_or_else(|| anyhow::anyhow!("no community model shipped"))?;
        let dispatched = match trained_round {
            Some(r) => r,
            None => s.dispatch_round.get(&entry.learner_id).copied().unwrap_or(0),
        };
        let staleness = s.community_round.saturating_sub(dispatched) as f64;
        let w = (1.0 + staleness).powf(-alpha) * 0.5;
        let models = [Arc::clone(&current), Arc::clone(&entry.model)];
        let coeffs = [1.0 - w, w];
        let mixed =
            Arc::new(aggregation::WeightedSum::compute(&models, &coeffs, &backend)?);
        let previous = s.community.replace(mixed);
        drop(models);
        drop(current);
        if let (Some(prev), Some(scratch)) = (previous, backend.scratch()) {
            scratch.reclaim_model(prev);
        }
        if async_update {
            s.community_round += 1;
            s.async_updates += 1;
            // Next task for this learner is dispatched against the new
            // round, and the learner is idle until the scheduler
            // re-dispatches.
            let community_round = s.community_round;
            s.dispatch_round.insert(entry.learner_id.clone(), community_round);
            s.outstanding.remove(&entry.learner_id);
        } else {
            self.late_folds.incr();
        }
        Ok(s.async_updates)
    }

    /// Number of async community updates applied so far.
    pub fn async_updates(&self) -> u64 {
        self.state.lock().unwrap().async_updates
    }

    /// Async protocol: does this learner need a fresh task?
    pub(crate) fn learner_needs_task(&self, id: &str) -> bool {
        !self.state.lock().unwrap().outstanding.contains(id)
    }

    /// Async protocol: note that a task is in flight for this learner
    /// (also stamps the dispatch time for the RTT profile sample).
    pub(crate) fn mark_task_outstanding(&self, id: &str) {
        let _rec = self.trace(|r, tick| r.mark_outstanding(tick, id));
        let now = self.clock.now();
        let mut s = self.state.lock().unwrap();
        s.outstanding.insert(id.to_string());
        s.task_sent_at.insert(id.to_string(), now);
    }

    /// Dispatch one message to `targets` concurrently. The message is
    /// serialized ONCE and the same bytes fan out to every learner
    /// (§Perf: dispatch used to re-encode the full model per learner).
    /// Returns `(dispatch_time, per-learner results)` where
    /// `dispatch_time` is the wall-clock until every request had been
    /// submitted (the paper's "task dispatch time"); the results include
    /// the full reply wait. Used for both train (fire-and-forget + Ack)
    /// and eval (blocking reply) dispatches.
    fn broadcast(
        &self,
        targets: &[Arc<LearnerHandle>],
        msg: &Message,
    ) -> (Duration, Vec<(String, Result<Message>)>) {
        let psk = self.psk;
        let encoded = msg.encode();
        self.broadcast_with(targets, |i, origin| {
            targets[i].rpc_raw_timed(psk, &encoded, origin)
        })
    }

    /// [`Controller::broadcast`] with per-target frames assembled from
    /// one shared `prefix` plus a small per-target suffix
    /// (`prefix ‖ suffixes[i]` goes to `targets[i]`): the pacing-aware
    /// one-shot dispatch path, where every learner's `RunTask` shares
    /// one model serialization but carries its own step budget (see
    /// [`Message::encode_run_task_parts`]). Frames materialize inside
    /// the dispatch pool, so live whole-model copies are bounded by the
    /// pool width, not the fleet size.
    fn broadcast_prefixed(
        &self,
        targets: &[Arc<LearnerHandle>],
        prefix: &[u8],
        suffixes: &[Vec<u8>],
    ) -> (Duration, Vec<(String, Result<Message>)>) {
        assert_eq!(suffixes.len(), targets.len(), "one suffix per target");
        let psk = self.psk;
        self.broadcast_with(targets, |i, origin| {
            let mut frame = Vec::with_capacity(prefix.len() + suffixes[i].len());
            frame.extend_from_slice(prefix);
            frame.extend_from_slice(&suffixes[i]);
            targets[i].rpc_raw_timed(psk, &frame, origin)
        })
    }

    /// Shared fan-out tail: run `send(i, origin)` for every target on
    /// the dispatch pool, take the slowest send-completion offset as
    /// the round's dispatch time (offsets are measured from `origin`,
    /// so bounded-pool queueing delay is included — as it is in every
    /// framework the paper measures), and pair replies with target ids.
    fn broadcast_with(
        &self,
        targets: &[Arc<LearnerHandle>],
        send: impl Fn(usize, Timestamp) -> Result<(Message, Duration)> + Send + Sync,
    ) -> (Duration, Vec<(String, Result<Message>)>) {
        let origin = self.clock.now();
        let results =
            self.dispatch_pool.parallel_map(targets.len(), |i| send(i, origin));
        let dispatch: Duration = results
            .iter()
            .filter_map(|r| r.as_ref().ok().map(|(_, sent_at)| *sent_at))
            .max()
            .unwrap_or(Duration::ZERO);
        let out = targets
            .iter()
            .zip(results)
            .map(|(h, r)| (h.id.clone(), r.map(|(reply, _)| reply)))
            .collect();
        (dispatch, out)
    }

    /// The selector configured in the env (`selector` block, falling
    /// back to the classic participation-fraction policy).
    fn selector(&self) -> Selector {
        match &self.env.selector {
            SelectorSpec::Participation => Selector::from_participation(self.env.participation),
            SelectorSpec::Freshness { k } => Selector::FreshnessAware { k: *k },
            SelectorSpec::Pacing { k, freshness_rounds } => {
                Selector::PacingAware { k: *k, freshness_rounds: *freshness_rounds }
            }
        }
    }

    /// Select round participants per the env's selection policy, fed by
    /// participation history and the pacing profiles.
    fn select_participants(&self, rng: &mut crate::util::Rng) -> Vec<Arc<LearnerHandle>> {
        let learners = self.learners_snapshot();
        let ids: Vec<String> = learners.iter().map(|l| l.id.clone()).collect();
        let (last, round) = {
            let s = self.state.lock().unwrap();
            (s.last_participation.clone(), s.community_round + 1)
        };
        let scores = self.pacing.scores();
        let ctx = SelectionCtx { last_round: &last, scores: &scores, round };
        let chosen = self.selector().select(&ids, &ctx, rng);
        let set: HashSet<&String> = chosen.iter().collect();
        learners.into_iter().filter(|l| set.contains(&l.id)).collect()
    }

    // ---- model ingest bookkeeping ------------------------------------

    /// High-water mark of wire-payload bytes held for model ingest. With
    /// one-shot uploads this reaches `Σ in-flight models' byte size`;
    /// with the streaming data plane it is bounded by
    /// `chunk size × in-flight streams` (asserted end-to-end in
    /// `tests/streaming.rs`).
    pub fn peak_wire_ingest_bytes(&self) -> usize {
        self.ingest.peak_wire_bytes()
    }

    /// Streams currently open on the inbound data plane.
    pub fn open_streams(&self) -> usize {
        self.ingest.open_streams()
    }

    /// Data-plane byte totals across both directions: `(sent, raw)`
    /// where `sent` is payload bytes that actually crossed the wire
    /// (dispatch egress + upload ingress) and `raw` is their
    /// f32-equivalent volume. `raw - sent` is what the wire codecs kept
    /// off the network (`FederationReport::wire_bytes_saved`).
    pub fn wire_bytes_totals(&self) -> (u64, u64) {
        let sent = self.dispatch_wire_sent.get() + self.ingest.recv_wire_bytes();
        let raw = self.dispatch_wire_raw.get() + self.ingest.recv_raw_bytes();
        (sent, raw)
    }

    // ---- data plane: inbound model streams ---------------------------
    //
    // The stream engine itself lives in `proto::ingest` (shared with the
    // learner's inbound side); the controller resolves what a stream
    // *means*: which purposes it accepts, where delta bases come from,
    // which buffer pool decode writes into, and what happens at `End`.
    // None of this touches the `CtrlState` mutex until the final,
    // already-decoded hand-off — exactly like the decode-before-lock
    // one-shot path.

    /// Resolve the shared delta base a peer announced: our community
    /// model, if its round matches the announced identity — else the
    /// model we last streamed to *this* learner (per-learner base map),
    /// which keeps delta uploads working when the community has already
    /// moved past the round the learner trained on (async staleness).
    fn delta_base_for(&self, learner_id: &str, base_round: u64) -> Option<Arc<TensorModel>> {
        {
            let s = self.state.lock().unwrap();
            if let Some(m) = &s.community {
                if s.community_round == base_round {
                    return Some(Arc::clone(m));
                }
            }
        }
        self.learner_bases
            .lock()
            .unwrap()
            .get(learner_id)
            .filter(|(round, _)| *round == base_round)
            .map(|(_, m)| m)
    }

    fn on_stream_begin(&self, args: StreamBegin) -> Message {
        if !matches!(
            args.purpose,
            StreamPurpose::ShipModel
                | StreamPurpose::TaskCompletion
                | StreamPurpose::PartialAggregate
        ) {
            return Message::error(
                ErrorCode::Unsupported,
                "controller accepts only upload streams \
                 (ShipModel / TaskCompletion / PartialAggregate)",
            );
        }
        let base = if args.codec.needs_base() {
            self.delta_base_for(&args.learner_id, args.base_round)
        } else {
            None
        };
        // Pre-size the decode buffers from the arena (when the backend
        // owns one): a steady-state streamed round re-fills the buffers
        // the previous community model and evicted contributions vacated.
        let pool = self
            .effective_backend()
            .scratch()
            .cloned()
            .map(|a| a as Arc<dyn BufferPool>);
        self.ingest.begin(args, pool, base)
    }

    fn on_stream_end(&self, stream_id: u64, digest: u64) -> Message {
        let finished = match self.ingest.end(stream_id, digest) {
            Ok(f) => f,
            Err(reply) => return reply,
        };
        let FinishedStream { purpose, task_id, learner_id, meta, model, .. } = finished;
        match purpose {
            StreamPurpose::ShipModel => {
                self.ship_model(model);
                Message::Ack { task_id: stream_id, ok: true }
            }
            // A shard's partial aggregate rides the completion path: the
            // aggregator is registered as a learner-like peer, its
            // partial weighted sum is the "trained model", and the shard
            // total weight arrives in `meta.num_samples` — so the root's
            // quorum barrier, staleness watermark, and FedAvg reweighting
            // all generalize over shards with no extra state.
            StreamPurpose::TaskCompletion | StreamPurpose::PartialAggregate => {
                match self.complete_task(task_id, learner_id, model, meta) {
                    Ok(()) => Message::Ack { task_id: stream_id, ok: true },
                    Err(e) => Message::error(ErrorCode::Internal, format!("{e:#}")),
                }
            }
            // `on_stream_begin` refuses dispatch purposes, so none can
            // reach `End`.
            _ => Message::error(ErrorCode::Unsupported, "unexpected dispatch stream"),
        }
    }

    // ---- data plane: streamed dispatch (controller → learners) -------

    /// Wire codec streamed dispatch fans models out with, resolved from
    /// the env (`auto` prefers delta when dispatch streams, since the
    /// stream itself establishes the shared base).
    fn dispatch_codec(&self) -> CodecId {
        self.env.dispatch_codec()
    }

    /// Codec `encode` calls performed by streamed dispatch so far — the
    /// encode-once fan-out probe.
    pub fn dispatch_encode_count(&self) -> u64 {
        self.dispatch_encodes.get()
    }

    /// Codec the next fan-out will use: the configured dispatch codec,
    /// degraded to what every reachable target's `Hello` handshake
    /// accepted. Mixed fleets intersect instead of erroring at `Begin`:
    /// delta-rle falls back to delta when some peer lacks the framed
    /// codec, and anything else falls back to the universal f32 floor.
    fn negotiate_dispatch_codec(&self, targets: &[Arc<LearnerHandle>]) -> CodecId {
        let configured = self.dispatch_codec();
        if configured == CodecId::F32 || targets.is_empty() {
            return configured;
        }
        let psk = self.psk;
        let sets = self
            .dispatch_pool
            .parallel_map(targets.len(), |i| targets[i].accepted_codecs(psk));
        // Unreachable targets (None) don't veto: their dispatch fails on
        // its own terms either way. Degrading per reachable target and
        // taking the weakest result walks the shared lossless chain
        // (CodecId::degrade_to) exactly once per peer.
        let degraded = sets
            .iter()
            .flatten()
            .map(|set| configured.degrade_to(set))
            .min_by_key(|c| match c {
                CodecId::F32 => 0,
                CodecId::Delta => 1,
                _ => 2,
            })
            .unwrap_or(configured);
        if degraded != configured {
            log_debug(
                "controller",
                &format!(
                    "dispatch codec degraded {configured} -> {degraded} (fleet intersection)"
                ),
            );
        }
        degraded
    }

    /// Stream one model to every target over the data plane, encoding
    /// each payload chunk ONCE and fanning the same frame bytes out to
    /// all learners (`send_raw`), so per-round controller encode work is
    /// O(model) and peak egress memory is O(chunk) — instead of the
    /// one-shot broadcast's whole-model frame. All targets share one
    /// stream id: ids only need to be unique per *receiver*.
    ///
    /// Learners that refuse a delta `Begin` with `NotFound` (no shared
    /// base yet) fall back to an individual full-f32 stream after the
    /// shared walk (`delta_fallback` env field). Returns
    /// `(dispatch_time, per-learner final End replies)` mirroring
    /// [`Controller::broadcast`]; for [`StreamPurpose::Evaluate`] the
    /// final reply is the in-call `EvaluateModelReply`.
    ///
    /// `budgets` (pacing-aware semi-sync) gives learner `i` its own
    /// `step_budget` override: only the small `Begin` frame is encoded
    /// per target — the payload chunk fan-out stays encode-once.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn stream_broadcast(
        &self,
        targets: &[Arc<LearnerHandle>],
        purpose: StreamPurpose,
        task_id: u64,
        spec: &TaskSpec,
        budgets: Option<&[usize]>,
        model: &Arc<TensorModel>,
        model_round: u64,
    ) -> (Duration, Vec<(String, Result<Message>)>) {
        #[derive(Clone, Copy, PartialEq)]
        enum SendState {
            Alive,
            NeedsFull,
            Done,
        }
        let psk = self.psk;
        let origin = self.clock.now();
        let n = targets.len();
        if let Some(bs) = budgets {
            assert_eq!(bs.len(), n, "one step budget per target");
        }
        let chunk_bytes = self.env.effective_stream_chunk().max(1);
        let configured = self.negotiate_dispatch_codec(targets);
        let (codec, base, base_round) = if configured.needs_base() {
            match self.last_broadcast.lock().unwrap().clone() {
                Some((round, m)) => (configured, Some(m), round),
                // Nothing fanned out yet: the first dispatch is full, and
                // it establishes the base for the next one.
                None => (CodecId::F32, None, 0),
            }
        } else {
            (configured, None, 0)
        };
        let stream_id = client::next_stream_id();
        // One span for the whole fan-out (per-target spans would cost
        // O(fleet) on the hot path); its context rides every Begin's
        // meta so each receiver parents its work under this dispatch.
        let dispatch_span = self
            .spans
            .begin(dispatch_op(purpose), self.work_ctx())
            .round(model_round)
            .task(task_id)
            .stream(stream_id);
        let dispatch_ctx = dispatch_span.ctx();
        let mut state = vec![SendState::Alive; n];
        let mut replies: Vec<Option<Result<Message>>> = (0..n).map(|_| None).collect();
        let mut dispatch = Duration::ZERO;

        // Begin fan-out: one encode + shared bytes normally; with
        // per-learner budgets, one (small) Begin per target — the spec
        // is the only thing that differs, and the payload chunks below
        // are still encoded once for everyone.
        let spec_for = |i: usize| match budgets {
            Some(bs) => TaskSpec { step_budget: bs[i], ..spec.clone() },
            None => spec.clone(),
        };
        let make_begin = |s: TaskSpec| {
            Message::ModelStreamBegin {
                stream_id,
                task_id,
                round: model_round,
                purpose,
                learner_id: String::new(),
                codec,
                base_round,
                layout: TensorLayoutProto::codec_layout_of(model, codec),
                meta: TaskMeta::default().with_span_ctx(dispatch_ctx),
                spec: s,
            }
            .encode()
        };
        let begin_frames: Vec<Vec<u8>> = match budgets {
            Some(_) => (0..n).map(|i| make_begin(spec_for(i))).collect(),
            None => vec![make_begin(spec.clone())],
        };
        let acks = self.dispatch_pool.parallel_map(n, |i| {
            let frame = if begin_frames.len() == 1 { &begin_frames[0] } else { &begin_frames[i] };
            targets[i].rpc_raw_timed(psk, frame, origin)
        });
        for (i, r) in acks.into_iter().enumerate() {
            match r {
                Ok((reply, sent_at)) => {
                    dispatch = dispatch.max(sent_at);
                    match client::ack_of(&reply) {
                        Ok(_) => {}
                        Err(e)
                            if e.remote_code() == Some(ErrorCode::NotFound)
                                && codec.needs_base()
                                && self.env.delta_fallback =>
                        {
                            state[i] = SendState::NeedsFull;
                        }
                        Err(e) => {
                            state[i] = SendState::Done;
                            replies[i] = Some(Err(anyhow::anyhow!(
                                "stream dispatch begin refused: {e}"
                            )));
                        }
                    }
                }
                Err(e) => {
                    state[i] = SendState::Done;
                    replies[i] = Some(Err(e));
                }
            }
        }

        // Chunk walk: a double-buffered two-stage pipeline. A producer
        // thread encodes payload chunk N+1 (codec encode + message
        // framing, each exactly ONCE) while this thread fans chunk N's
        // bytes out to every learner — compression overlaps the network.
        // Channel depth 1 = one frame in flight + one being encoded.
        let mut digest = FNV64_INIT;
        if state.iter().any(|s| *s == SendState::Alive) {
            let (frame_tx, frame_rx) =
                std::sync::mpsc::sync_channel::<(usize, usize, Vec<u8>)>(1);
            let (walk_digest, ser_time) = std::thread::scope(|scope| {
                let producer_base = base.clone();
                let producer = scope.spawn(move || {
                    let codec_impl = codec.codec();
                    let mut digest = FNV64_INIT;
                    let mut ser = Duration::ZERO;
                    let mut seq = 0u64;
                    let esz = codec.wire_dtype().size_bytes();
                    let block = (chunk_bytes / 4).max(1);
                    'walk: for (ti, t) in model.tensors.iter().enumerate() {
                        let tensor_base =
                            producer_base.as_ref().map(|b| &b.tensors[ti].data[..]);
                        if codec_impl.is_framed() {
                            // One self-delimiting compressed frame per
                            // element block, never split on the wire.
                            // Mirrors `client::stream_model_with`'s
                            // framed walk (same `chunk_bytes / 4` block
                            // formula, same digest fold) — keep the two
                            // in lockstep.
                            let mut lo = 0usize;
                            while lo < t.data.len() {
                                let hi = (lo + block).min(t.data.len());
                                let sw = Stopwatch::start();
                                let mut payload = Vec::with_capacity((hi - lo) + 16);
                                codec_impl.encode_frame_into(
                                    &t.data[lo..hi],
                                    tensor_base.map(|b| &b[lo..hi]),
                                    &mut payload,
                                );
                                ser += sw.elapsed();
                                self.dispatch_encodes.incr();
                                digest = fnv1a64(digest, &payload);
                                let raw_equiv = (hi - lo) * 4;
                                let payload_len = payload.len();
                                let frame =
                                    Message::ModelChunk { stream_id, seq, bytes: payload }
                                        .encode();
                                if frame_tx.send((raw_equiv, payload_len, frame)).is_err() {
                                    break 'walk; // every target died
                                }
                                seq += 1;
                                lo = hi;
                            }
                        } else {
                            let sw = Stopwatch::start();
                            let bytes = codec_impl.encode(&t.data, tensor_base);
                            ser += sw.elapsed();
                            self.dispatch_encodes.incr();
                            for part in bytes.chunks(chunk_bytes) {
                                digest = fnv1a64(digest, part);
                                let raw_equiv = part.len() * 4 / esz;
                                let frame = Message::ModelChunk {
                                    stream_id,
                                    seq,
                                    bytes: part.to_vec(),
                                }
                                .encode();
                                if frame_tx.send((raw_equiv, part.len(), frame)).is_err() {
                                    break 'walk;
                                }
                                seq += 1;
                            }
                        }
                    }
                    (digest, ser)
                });
                for (raw_equiv, payload_len, frame) in frame_rx.iter() {
                    let live = state.iter().filter(|s| **s == SendState::Alive).count();
                    if live == 0 {
                        break;
                    }
                    self.dispatch_wire_sent.add((payload_len * live) as u64);
                    self.dispatch_wire_raw.add((raw_equiv * live) as u64);
                    let results = self.dispatch_pool.parallel_map(n, |i| {
                        (state[i] == SendState::Alive)
                            .then(|| targets[i].rpc_raw_timed(psk, &frame, origin))
                    });
                    for (i, r) in results.into_iter().enumerate() {
                        match r {
                            None => {}
                            Some(Ok((reply, sent_at))) => {
                                dispatch = dispatch.max(sent_at);
                                if let Err(e) = client::ack_of(&reply) {
                                    state[i] = SendState::Done;
                                    replies[i] = Some(Err(anyhow::anyhow!(
                                        "stream dispatch chunk refused: {e}"
                                    )));
                                }
                            }
                            Some(Err(e)) => {
                                state[i] = SendState::Done;
                                replies[i] = Some(Err(e));
                            }
                        }
                    }
                }
                drop(frame_rx);
                producer.join().expect("dispatch encode thread panicked")
            });
            digest = walk_digest;
            self.record(FedOp::Serialization, ser_time);
        }

        // End fan-out; the reply is the purpose's final answer.
        let end = Message::ModelStreamEnd { stream_id, digest }.encode();
        let results = self.dispatch_pool.parallel_map(n, |i| {
            (state[i] == SendState::Alive).then(|| targets[i].rpc_raw_timed(psk, &end, origin))
        });
        for (i, r) in results.into_iter().enumerate() {
            match r {
                None => {}
                Some(Ok((reply, sent_at))) => {
                    dispatch = dispatch.max(sent_at);
                    replies[i] = Some(Ok(reply));
                    state[i] = SendState::Done;
                }
                Some(Err(e)) => {
                    replies[i] = Some(Err(e));
                    state[i] = SendState::Done;
                }
            }
        }

        // Individual full-codec retries for learners without the base,
        // in parallel — k cold learners must not serialize k whole-model
        // streams onto the round's critical path.
        if state.iter().any(|s| *s == SendState::NeedsFull) {
            let fallback_results = self.dispatch_pool.parallel_map(n, |i| {
                (state[i] == SendState::NeedsFull).then(|| {
                    let h = &targets[i];
                    self.fallback_sends.incr();
                    log_debug(
                        "controller",
                        &format!("{}: no shared delta base, re-sending full", h.id),
                    );
                    let meta = TaskMeta::default().with_span_ctx(dispatch_ctx);
                    let spec_i = spec_for(i);
                    let send = StreamSend::f32(
                        purpose,
                        task_id,
                        model_round,
                        "",
                        model,
                        &meta,
                        &spec_i,
                        chunk_bytes,
                    );
                    client::stream_model_with(
                        &mut |msg| {
                            // The re-stream is real wire traffic: keep
                            // the gauges honest (f32 ⇒ sent == raw).
                            if let Message::ModelChunk { bytes, .. } = &msg {
                                let len = bytes.len() as u64;
                                self.dispatch_wire_sent.add(len);
                                self.dispatch_wire_raw.add(len);
                            }
                            match h.rpc(psk, &msg) {
                                Ok(Message::Error { code, detail }) => {
                                    Err(client::RpcError::Remote { code, detail })
                                }
                                Ok(reply) => Ok(reply),
                                Err(e) => Err(client::RpcError::Transport(e)),
                            }
                        },
                        &send,
                    )
                })
            });
            for (i, r) in fallback_results.into_iter().enumerate() {
                let Some(r) = r else { continue };
                replies[i] = Some(match r {
                    Ok(reply) => Ok(reply),
                    Err(e) => Err(anyhow::anyhow!("full-codec fallback stream failed: {e}")),
                });
            }
            dispatch = dispatch.max(self.clock.since(origin));
        }

        // A lossless fan-out becomes the shared base for the next
        // delta-coded dispatch — but only if at least one learner
        // actually received the model (a wholly failed fan-out must not
        // install a base nobody holds: with `delta_fallback: false`
        // every later dispatch would be refused and the federation could
        // never recover). The base it displaces is usually the
        // just-superseded community model, whose arena recycling was
        // blocked at aggregation time by exactly this handle — hand it
        // back now that nothing else holds it, so delta dispatch keeps
        // the steady-state zero-allocation property.
        let any_delivered = replies
            .iter()
            .any(|r| matches!(r, Some(Ok(m)) if !matches!(m, Message::Error { .. })));
        // Per-learner base map first: every learner that acknowledged a
        // lossless stream now holds `model` bit-exactly (the f32
        // fallback is lossless too). Overwriting entries here also drops
        // their handles on the displaced shared base, so the rotation
        // below sees a unique Arc and can recycle its buffers.
        if codec.is_lossless() {
            let delivered: Vec<usize> = replies
                .iter()
                .enumerate()
                .filter(|(_, r)| matches!(r, Some(Ok(m)) if !matches!(m, Message::Error { .. })))
                .map(|(i, _)| i)
                .collect();
            // Record each pin before installing it, and hold the
            // recorder lock across the inserts (recorder → bases order,
            // same as the upload plane's base resolution).
            let _rec = self.trace(|r, tick| {
                for &i in &delivered {
                    r.base_set(tick, &targets[i].id, model_round);
                }
            });
            let displaced: Vec<Arc<TensorModel>> = {
                let mut bases = self.learner_bases.lock().unwrap();
                delivered
                    .iter()
                    .flat_map(|&i| bases.insert(&targets[i].id, model_round, Arc::clone(model)))
                    .collect()
            };
            // LRU evictions and same-learner displacements both leave
            // circulation here; uniquely-owned buffers go back to the
            // arena (in a sync fleet they all alias `model`, so this is
            // a no-op until the map's last handle drops elsewhere).
            if let Some(scratch) = self.effective_backend().scratch() {
                for old in displaced {
                    if !Arc::ptr_eq(&old, model) {
                        scratch.reclaim_model(old);
                    }
                }
            }
        }
        if any_delivered && configured.needs_base() && codec.is_lossless() {
            let displaced = self
                .last_broadcast
                .lock()
                .unwrap()
                .replace((model_round, Arc::clone(model)));
            if let Some((_, old)) = displaced {
                if !Arc::ptr_eq(&old, model) {
                    if let Some(scratch) = self.effective_backend().scratch() {
                        scratch.reclaim_model(old);
                    }
                }
            }
        }

        let out = targets
            .iter()
            .zip(replies)
            .map(|(h, r)| {
                (
                    h.id.clone(),
                    r.unwrap_or_else(|| Err(anyhow::anyhow!("stream dispatch incomplete"))),
                )
            })
            .collect();
        (dispatch, out)
    }

    /// Stream one model to a single learner — the async protocol's
    /// re-dispatch path. There is no fan-out to share, but the codec
    /// wins carry over: delta/delta-rle encode against the last model
    /// *this* learner acknowledged (per-learner base map), with the
    /// standard full-f32 fallback when no base is shared. Returns the
    /// stream's final `End` reply.
    pub(crate) fn stream_to_learner(
        &self,
        target: &Arc<LearnerHandle>,
        purpose: StreamPurpose,
        task_id: u64,
        spec: &TaskSpec,
        model: &Arc<TensorModel>,
        model_round: u64,
    ) -> Result<Message> {
        let psk = self.psk;
        let configured = match target.accepted_codecs(psk) {
            Some(accepted) => self.dispatch_codec().degrade_to(&accepted),
            None => self.dispatch_codec(),
        };
        let (codec, base, base_round) = if configured.needs_base() {
            match self.learner_bases.lock().unwrap().get(&target.id) {
                Some((round, m)) => (configured, Some(m), round),
                // Nothing acknowledged yet — or the LRU cap evicted
                // this learner's base: full send (re)establishes one.
                None => (CodecId::F32, None, 0),
            }
        } else {
            (configured, None, 0)
        };
        let dispatch_span = self
            .spans
            .begin(dispatch_op(purpose), self.work_ctx())
            .peer(&target.id)
            .round(model_round)
            .task(task_id);
        let meta = TaskMeta::default().with_span_ctx(dispatch_span.ctx());
        let send = StreamSend {
            purpose,
            task_id,
            round: model_round,
            learner_id: "",
            model: model.as_ref(),
            meta: &meta,
            spec,
            codec,
            base: base.as_deref(),
            base_round,
            chunk_bytes: self.env.effective_stream_chunk().max(1),
        };
        // One attempt = one codec, so the wire gauges stay exact per
        // chunk whether the stream succeeds, fails, or falls back: sent
        // counts encoded payload bytes, raw counts their f32-equivalent
        // (frame header parse for framed codecs, dtype ratio otherwise).
        let run_attempt = |send: &StreamSend<'_>| {
            let codec = send.codec;
            client::stream_model_with(
                &mut |msg: Message| {
                    if let Message::ModelChunk { bytes, .. } = &msg {
                        self.dispatch_wire_sent.add(bytes.len() as u64);
                        let raw = if codec.is_framed() {
                            codec
                                .codec()
                                .frame_elems(bytes)
                                .map(|n| (n * 4) as u64)
                                .unwrap_or(bytes.len() as u64)
                        } else {
                            (bytes.len() * 4 / codec.wire_dtype().size_bytes()) as u64
                        };
                        self.dispatch_wire_raw.add(raw);
                    }
                    match target.rpc(psk, &msg) {
                        Ok(Message::Error { code, detail }) => {
                            Err(client::RpcError::Remote { code, detail })
                        }
                        Ok(reply) => Ok(reply),
                        Err(e) => Err(client::RpcError::Transport(e)),
                    }
                },
                send,
            )
        };
        // Transport faults (dial refused, connection severed mid-stream)
        // retry through the unified policy — each attempt restarts the
        // stream under a fresh id, and the ingest's per-(task, learner)
        // watermark makes a replayed completion idempotent. Application
        // errors never retry; the NotFound delta-base miss resolves
        // inside a single attempt via the full-f32 fallback.
        let mut rng =
            Rng::new(self.env.seed ^ task_id ^ fnv1a64(FNV64_INIT, target.id.as_bytes()));
        let reply = RetryPolicy::rpc()
            .run(
                &self.clock,
                &mut rng,
                |_| match run_attempt(&send) {
                    Err(client::RpcError::Remote { code: ErrorCode::NotFound, .. })
                        if codec.needs_base() && self.env.delta_fallback =>
                    {
                        // The learner lost the base (restart / staleness):
                        // the standard full-f32 retry, mirroring
                        // `stream_model_with_fallback`.
                        self.fallback_sends.incr();
                        let full = StreamSend {
                            codec: CodecId::F32,
                            base: None,
                            base_round: 0,
                            ..send.clone()
                        };
                        run_attempt(&full)
                    }
                    other => other,
                },
                |e| e.is_transport(),
            )
            .map_err(|give_up| {
                if give_up.exhausted {
                    self.retry_give_ups.incr();
                    anyhow::anyhow!(
                        "streamed dispatch to {}: gave up after {} attempts in {:?}: {}",
                        target.id,
                        give_up.attempts,
                        give_up.elapsed,
                        give_up.last_error
                    )
                } else {
                    anyhow::anyhow!("streamed dispatch to {}: {}", target.id, give_up.last_error)
                }
            })?;
        if codec.is_lossless() && !matches!(reply, Message::Error { .. }) {
            let _rec = self.trace(|r, tick| r.base_set(tick, &target.id, model_round));
            let displaced = self
                .learner_bases
                .lock()
                .unwrap()
                .insert(&target.id, model_round, Arc::clone(model));
            if let Some(scratch) = self.effective_backend().scratch() {
                for old in displaced {
                    if !Arc::ptr_eq(&old, model) {
                        scratch.reclaim_model(old);
                    }
                }
            }
        }
        Ok(reply)
    }
}

/// Span op name for an outbound model fan-out, by stream purpose.
fn dispatch_op(purpose: StreamPurpose) -> &'static str {
    match purpose {
        StreamPurpose::Evaluate => "eval_dispatch",
        _ => "dispatch",
    }
}

impl Service for Controller {
    fn handle(&self, msg: Message) -> Message {
        // Record the frame byte-exact and hold the recorder lock across
        // the whole dispatch: the live timeline is serialized in exactly
        // the order a replay re-applies it.
        let _rec = self.trace(|r, tick| r.inbound(tick, &msg.encode()));
        self.handle_inner(msg)
    }
}

impl Controller {
    /// The actual RPC dispatch ([`Service::handle`] wraps it with the
    /// trace hook). Must never call back into `handle` or `ship_model`:
    /// the recorder lock is held across the whole dispatch.
    fn handle_inner(&self, msg: Message) -> Message {
        if self.is_shutdown() {
            return Message::error(ErrorCode::Unavailable, "controller is shut down");
        }
        match msg {
            Message::Hello { proto_version, codecs } => {
                if proto_version == PROTO_VERSION {
                    Message::HelloAck {
                        proto_version: PROTO_VERSION,
                        component: "controller".into(),
                        codecs: crate::tensor::codec::negotiate(
                            &codecs,
                            &client::SUPPORTED_CODECS,
                        ),
                    }
                } else {
                    Message::error(
                        ErrorCode::VersionMismatch,
                        format!("controller speaks v{PROTO_VERSION}, peer v{proto_version}"),
                    )
                }
            }
            Message::Register { learner_id, host, port, num_samples } => {
                // `host` may be a full endpoint (inproc://… or tcp://…)
                // or a bare hostname + port pair.
                let endpoint = if host.contains("://") {
                    host
                } else {
                    format!("tcp://{host}:{port}")
                };
                let idx = self.register_learner(&learner_id, &endpoint, num_samples);
                Message::RegisterAck { accepted: true, assigned_index: idx }
            }
            Message::Deregister { learner_id } => {
                if self.deregister_learner(&learner_id) {
                    Message::Ack { task_id: 0, ok: true }
                } else {
                    Message::error(
                        ErrorCode::NotFound,
                        format!("learner '{learner_id}' is not registered"),
                    )
                }
            }
            Message::ShipModel { model } => {
                // Decode outside every lock; the wire buffer is released
                // before the model is installed.
                let wire = model.byte_size();
                self.ingest.wire_hold(wire);
                let decoded = model.to_model();
                drop(model);
                self.ingest.wire_release(wire);
                match decoded {
                    Ok(m) => {
                        // Not `ship_model`: the handle wrapper already
                        // recorded this frame (and holds the recorder).
                        self.install_model(m);
                        Message::Ack { task_id: 0, ok: true }
                    }
                    Err(e) => Message::error(ErrorCode::InvalidModel, format!("bad model: {e:#}")),
                }
            }
            Message::MarkTaskCompleted { task_id, learner_id, model, meta } => {
                // One-shot path: decode before touching any controller
                // lock. The gauge brackets exactly the wire buffer's
                // lifetime (held only while decoding) so the streamed
                // vs one-shot comparison in tests/streaming.rs measures
                // real memory, not an accounting artifact.
                let sw = Stopwatch::start();
                let wire = model.byte_size();
                self.ingest.wire_hold(wire);
                let decoded = model.to_model();
                drop(model);
                self.ingest.wire_release(wire);
                self.record(FedOp::Serialization, sw.elapsed());
                match decoded {
                    Err(e) => {
                        Message::error(ErrorCode::InvalidModel, format!("bad model: {e:#}"))
                    }
                    Ok(m) => match self.complete_task(task_id, learner_id, m, meta) {
                        Ok(()) => Message::Ack { task_id, ok: true },
                        Err(e) => Message::error(ErrorCode::Internal, format!("{e:#}")),
                    },
                }
            }
            Message::ModelStreamBegin {
                stream_id,
                task_id,
                round,
                purpose,
                learner_id,
                codec,
                base_round,
                layout,
                meta,
                spec,
            } => self.on_stream_begin(StreamBegin {
                stream_id,
                task_id,
                round,
                purpose,
                learner_id,
                codec,
                base_round,
                layout,
                meta,
                spec,
            }),
            Message::ModelChunk { stream_id, seq, bytes } => {
                let sw = Stopwatch::start();
                let reply = self.ingest.chunk(stream_id, seq, bytes);
                self.record(FedOp::Serialization, sw.elapsed());
                reply
            }
            Message::ModelStreamEnd { stream_id, digest } => {
                self.on_stream_end(stream_id, digest)
            }
            Message::Heartbeat { .. } => {
                // The driver probes every `heartbeat_ms`, which makes
                // this a natural periodic sweep for streams abandoned by
                // a dead peer (otherwise they'd only be reclaimed when
                // the next streamed upload begins).
                self.ingest.gc_idle();
                let health = self.health_probe();
                Message::HeartbeatAck {
                    component: "controller".into(),
                    healthy: health.is_healthy(),
                    health,
                }
            }
            Message::GetModel => {
                // Snapshot under the lock, serialize after releasing it —
                // encoding a 10M-param model must not stall completions.
                match self.community() {
                    Some((m, round)) => Message::ModelReply {
                        model: ModelProto::from_model(&m, DType::F32, ByteOrder::Little),
                        round,
                    },
                    None => Message::error(ErrorCode::NotFound, "no community model"),
                }
            }
            Message::Shutdown => {
                self.shutdown.store(true, Ordering::SeqCst);
                self.round_cv.notify_all();
                Message::Ack { task_id: 0, ok: true }
            }
            other => {
                Message::error(ErrorCode::Unsupported, format!("unexpected {}", other.kind()))
            }
        }
    }
}

impl Controller {
    /// Decoded-model completion path shared by the one-shot and
    /// streaming ingests: fold the completion telemetry into the
    /// learner's pacing profile, store the model (T4–T5), and either
    /// tick the round barrier (sync/semi-sync), fold a late quorum-round
    /// completion through the async staleness path, or mix immediately
    /// (async).
    fn complete_task(
        &self,
        task_id: u64,
        learner_id: String,
        model: TensorModel,
        meta: TaskMeta,
    ) -> Result<()> {
        // Parent under the SENDER's span (the learner's upload attempt,
        // or an aggregator's partial upload), carried in the meta's
        // trace-context tail — this is the hop that stitches
        // cross-process work into one trace.
        let ingest_span = self
            .spans
            .begin("ingest", meta.span_ctx())
            .peer(&learner_id)
            .task(task_id);
        if let Protocol::Asynchronous { staleness_alpha } = self.env.protocol {
            return self.complete_task_async(task_id, learner_id, model, meta, staleness_alpha);
        }
        // Sync / semi-sync: every acceptance decision — round arrival,
        // profile observation, the completed-task watermark, whether
        // the model is stored, whether it late-folds — is made
        // atomically under ONE state lock, so a replayed or stale
        // retransmit cannot slip a model in between the checks (e.g.
        // clobbering the learner's fresh stored model right before its
        // round aggregates).
        let (entry, rtt, observe, late, community_round) = {
            let mut s = self.state.lock().unwrap();
            // Acceptance: the task was actually dispatched to this
            // learner — id known AND the claimed task id no newer than
            // its latest dispatch (a fabricated future id would zero
            // the staleness discount) — and not accepted before (the
            // watermark makes every path replay-idempotent: neither
            // the pacing EWMA/completion count nor the community model
            // may count one task twice).
            let latest_dispatch = s.dispatch_round.get(&learner_id).copied();
            let was_dispatched = latest_dispatch.is_some_and(|latest| task_id <= latest);
            let unseen = !s
                .completed_tasks
                .get(&learner_id)
                .is_some_and(|accepted| task_id <= *accepted);
            let accepted = was_dispatched && unseen;
            // Round membership additionally requires the ROUND's task
            // id: a straggler's completion from a closed quorum round
            // must not tick the next round's barrier with a stale
            // model — it takes the late-fold path below.
            let in_round = accepted
                && s.round
                    .as_ref()
                    .is_some_and(|r| r.round == task_id && r.expecting.contains(&learner_id));
            // A completion with no open round claiming it is "late" —
            // its round closed at the quorum cut. Under deadline-quorum
            // configs, fold it into the community model with the async
            // staleness discount instead of dropping the learner's
            // work on the floor. Scope: the fold mutates the community
            // model in place, so it reaches the fleet through the NEXT
            // dispatch; a fold landing after the next round already
            // dispatched is superseded when that round's FedAvg
            // replaces the community model (pure FedAvg keeps nothing
            // of `current` — see the ROADMAP keep-rate open item).
            let late = accepted
                && !in_round
                && s.community.is_some()
                && self.env.quorum_fraction < 1.0;
            let community_round = s.community_round;
            // Store FIRST — only accepted contributions (a refused
            // completion must not replace the learner's stored model,
            // which is the round's aggregation input) — and only THEN
            // mutate barrier/watermark/RTT state: a failed insert exits
            // here with nothing recorded, so the learner's retry is
            // not refused as a replay against a phantom arrival.
            let entry = if in_round || late {
                let insert_sw = Stopwatch::start();
                let entry = StoredModel {
                    learner_id: learner_id.clone(),
                    round: community_round,
                    meta: meta.clone(),
                    model: Arc::new(model),
                };
                s.store.insert(entry.clone())?;
                self.record(FedOp::StoreInsert, insert_sw.elapsed());
                Some(entry)
            } else {
                None
            };
            // RTT sample: only the first completion of the learner's
            // LATEST task may consume the send stamp (an older
            // straggler must not claim the fresh task's clock).
            let rtt = if accepted && latest_dispatch == Some(task_id) {
                s.task_sent_at.remove(&learner_id).map(|t| self.clock.since(t))
            } else {
                None
            };
            if accepted {
                s.completed_tasks.insert(learner_id.clone(), task_id);
            }
            if in_round {
                let r = s.round.as_mut().unwrap();
                let at = self.clock.since(r.opened_at);
                r.first_arrival.get_or_insert(at);
                r.last_arrival = Some(at);
                r.arrived.push(learner_id.clone());
            }
            (entry, rtt, accepted, late, community_round)
        };
        if observe {
            self.pacing.observe_completion(&learner_id, &meta, rtt, community_round);
        }
        if late {
            let entry = entry.as_ref().expect("late fold implies a stored entry");
            let _fold_span = self
                .spans
                .begin("late_fold", ingest_span.ctx())
                .peer(&learner_id)
                .task(task_id);
            let sw = Stopwatch::start();
            // Staleness basis = the round this model was trained for
            // (its task id), NOT the learner's dispatch_round entry —
            // re-selection may already have overwritten that with a
            // newer task.
            self.mix_completion(entry, self.env.quorum_late_alpha, false, Some(task_id))?;
            self.record(FedOp::Aggregation, sw.elapsed());
            log_debug(
                "controller",
                &format!("{learner_id}: late completion folded (staleness path)"),
            );
        }
        self.round_cv.notify_all();
        Ok(())
    }

    /// Async-protocol completion path: store (for inspection/metrics
    /// parity with sync) and mix immediately, discounted by staleness.
    fn complete_task_async(
        &self,
        task_id: u64,
        learner_id: String,
        model: TensorModel,
        meta: TaskMeta,
        staleness_alpha: f64,
    ) -> Result<()> {
        let (community_round, rtt, observe, unseen) = {
            let mut s = self.state.lock().unwrap();
            // Profile only learners the controller actually handed a
            // task (the async scheduler marks them outstanding; their
            // dispatch_round entry appears after the first mix).
            let known = s.dispatch_round.contains_key(&learner_id)
                || s.outstanding.contains(&learner_id);
            // Replay gate: async task ids are the community round a
            // task was dispatched at, strictly increasing per learner,
            // so the same watermark used by sync rounds makes the mix
            // idempotent — a retransmit after a lost ack must not
            // re-blend the same update (or double-tick async_updates).
            // `plausible` bounds the watermark a peer can claim: no
            // task beyond the next community round was ever dispatched,
            // so a fabricated huge task id can neither mix nor wedge
            // the learner's future completions behind a poisoned
            // watermark.
            let plausible = task_id <= s.community_round.saturating_add(1);
            let unseen = plausible
                && !s
                    .completed_tasks
                    .get(&learner_id)
                    .is_some_and(|accepted| task_id <= *accepted);
            if unseen {
                s.completed_tasks.insert(learner_id.clone(), task_id);
            }
            let rtt = if unseen {
                s.task_sent_at.remove(&learner_id).map(|t| self.clock.since(t))
            } else {
                None
            };
            (s.community_round, rtt, known && unseen, unseen)
        };
        if observe {
            self.pacing.observe_completion(&learner_id, &meta, rtt, community_round);
        }
        if !unseen {
            // Duplicate delivery: everything below already happened for
            // this task — ack idempotently.
            self.round_cv.notify_all();
            return Ok(());
        }
        let entry = StoredModel {
            learner_id,
            round: community_round,
            meta,
            model: Arc::new(model),
        };
        let sw = Stopwatch::start();
        {
            let mut s = self.state.lock().unwrap();
            let insert_sw = Stopwatch::start();
            s.store.insert(entry.clone())?;
            let evicted = s.store.evict(1)?;
            drop(s);
            self.record(FedOp::StoreInsert, insert_sw.elapsed());
            // Superseded uploads go back to the arena (see
            // aggregate_from_store).
            if let Some(scratch) = self.effective_backend().scratch() {
                for e in evicted {
                    scratch.reclaim_model(e.model);
                }
            }
        }
        self.async_mix(&entry, staleness_alpha)?;
        self.record(FedOp::Aggregation, sw.elapsed());
        self.round_cv.notify_all();
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{FederationEnv, ModelSpec};
    use crate::util::Rng;

    fn env() -> FederationEnv {
        FederationEnv::builder("ctrl-test")
            .learners(3)
            .model(ModelSpec::mlp(4, 2, 8))
            .build()
    }

    fn model(seed: u64) -> TensorModel {
        let layout = ModelSpec::mlp(4, 2, 8).tensor_layout();
        TensorModel::random_init(&layout, &mut Rng::new(seed))
    }

    #[test]
    fn register_and_ship_via_service() {
        let ctrl = Controller::new(env(), None).unwrap();
        let reply = ctrl.handle(Message::Register {
            learner_id: "l0".into(),
            host: "inproc://l0".into(),
            port: 0,
            num_samples: 100,
        });
        assert_eq!(reply, Message::RegisterAck { accepted: true, assigned_index: 0 });
        assert_eq!(ctrl.learner_count(), 1);

        let m = model(1);
        let reply = ctrl.handle(Message::ShipModel {
            model: ModelProto::from_model(&m, DType::F32, ByteOrder::Little),
        });
        assert_eq!(reply, Message::Ack { task_id: 0, ok: true });
        let (community, round) = ctrl.community().unwrap();
        assert_eq!(round, 0);
        assert!(community.max_abs_diff(&m) == 0.0);
    }

    #[test]
    fn completion_barrier_counts_expected_only() {
        let ctrl = Controller::new(env(), None).unwrap();
        ctrl.ship_model(model(1));
        ctrl.open_round(1, &["a".into(), "b".into()]);
        // Unexpected learner does not tick the barrier.
        let mp = ModelProto::from_model(&model(2), DType::F32, ByteOrder::Little);
        ctrl.handle(Message::MarkTaskCompleted {
            task_id: 1,
            learner_id: "zzz".into(),
            model: mp.clone(),
            meta: TaskMeta { num_samples: 10, ..Default::default() },
        });
        ctrl.handle(Message::MarkTaskCompleted {
            task_id: 1,
            learner_id: "a".into(),
            model: mp.clone(),
            meta: TaskMeta { num_samples: 10, ..Default::default() },
        });
        // Duplicate completion counted once.
        ctrl.handle(Message::MarkTaskCompleted {
            task_id: 1,
            learner_id: "a".into(),
            model: mp.clone(),
            meta: TaskMeta { num_samples: 10, ..Default::default() },
        });
        let arrived = ctrl.wait_round_completions(Duration::from_millis(50));
        assert_eq!(arrived, vec!["a".to_string()]); // timeout path
    }

    #[test]
    fn aggregate_from_store_updates_community() {
        let ctrl = Controller::new(env(), None).unwrap();
        ctrl.ship_model(model(1));
        let mp_a = ModelProto::from_model(&model(2), DType::F32, ByteOrder::Little);
        let mp_b = ModelProto::from_model(&model(3), DType::F32, ByteOrder::Little);
        ctrl.open_round(1, &["a".into(), "b".into()]);
        for (id, mp) in [("a", mp_a), ("b", mp_b)] {
            ctrl.handle(Message::MarkTaskCompleted {
                task_id: 1,
                learner_id: id.into(),
                model: mp,
                meta: TaskMeta { num_samples: 100, ..Default::default() },
            });
        }
        let arrived = ctrl.wait_round_completions(Duration::from_secs(1));
        assert_eq!(arrived.len(), 2);
        let new_model = ctrl.aggregate_from_store(&arrived, 1).unwrap();
        let (community, round) = ctrl.community().unwrap();
        assert_eq!(round, 1);
        assert_eq!(community, new_model);
        // Mean of the two models.
        let expect = 0.5 * model(2).tensors[0].data[0] + 0.5 * model(3).tensors[0].data[0];
        assert!((new_model.tensors[0].data[0] - expect).abs() < 1e-5);
    }

    #[test]
    fn chunked_steady_state_rounds_do_not_allocate_output_buffers() {
        use crate::config::{AggregationBackend, AggregationSpec};
        let mut e = env();
        e.aggregation = AggregationSpec {
            backend: AggregationBackend::Chunked,
            threads: 2,
            ..Default::default()
        };
        let ctrl = Controller::new(e, None).unwrap();
        ctrl.ship_model(model(1));
        let scratch = Arc::clone(ctrl.backend.scratch().expect("chunked backend"));
        let tensor_count = model(1).tensor_count();
        let mut allocs_per_round = Vec::new();
        for round in 1..=5u64 {
            ctrl.open_round(round, &["a".into(), "b".into()]);
            for (i, id) in ["a", "b"].into_iter().enumerate() {
                let m = model(100 + round * 2 + i as u64);
                ctrl.handle(Message::MarkTaskCompleted {
                    task_id: round,
                    learner_id: id.into(),
                    model: ModelProto::from_model(&m, DType::F32, ByteOrder::Little),
                    meta: TaskMeta { num_samples: 10, ..Default::default() },
                });
            }
            let arrived = ctrl.wait_round_completions(Duration::from_secs(1));
            assert_eq!(arrived.len(), 2);
            ctrl.aggregate_from_store(&arrived, round).unwrap();
            allocs_per_round.push(scratch.fresh_allocations());
        }
        // Round 1 pays one buffer per output tensor; every later round
        // reuses the buffers reclaimed from the replaced community model.
        assert_eq!(allocs_per_round[0], tensor_count);
        assert_eq!(
            allocs_per_round.last(),
            allocs_per_round.first(),
            "steady-state rounds allocated output buffers: {allocs_per_round:?}"
        );
    }

    #[test]
    fn streamed_steady_state_recycles_evicted_contributions() {
        // The full streamed round-trip allocation story: stream ingest
        // draws decode buffers from the arena, aggregation output draws
        // from the arena, and BOTH the replaced community model and the
        // store-evicted contributions (last round's uploads) go back.
        // Once warm (round 3+), a streamed round allocates nothing.
        use crate::config::{AggregationBackend, AggregationSpec};
        let mut e = env();
        e.aggregation = AggregationSpec {
            backend: AggregationBackend::Chunked,
            threads: 2,
            ..Default::default()
        };
        let ctrl = Controller::new(e, None).unwrap();
        ctrl.ship_model(model(1));
        let scratch = Arc::clone(ctrl.backend.scratch().expect("chunked backend"));
        let tensor_count = model(1).tensor_count();
        let chunk = 64usize;
        let mut allocs = Vec::new();
        for round in 1..=6u64 {
            ctrl.open_round(round, &["a".into(), "b".into()]);
            for (i, id) in ["a", "b"].into_iter().enumerate() {
                let m = model(200 + round * 2 + i as u64);
                stream_via_handle(
                    &ctrl,
                    StreamPurpose::TaskCompletion,
                    round,
                    id,
                    &m,
                    TaskMeta { num_samples: 10, ..Default::default() },
                    chunk,
                )
                .unwrap();
            }
            let arrived = ctrl.wait_round_completions(Duration::from_secs(1));
            assert_eq!(arrived.len(), 2);
            ctrl.aggregate_from_store(&arrived, round).unwrap();
            allocs.push(scratch.fresh_allocations());
        }
        // Warm-up: round 1 allocates 2 ingest models + 1 output (3T),
        // round 2 still misses what the first eviction hadn't returned
        // yet (2T more); from round 3 on, every buffer comes from the
        // arena.
        assert_eq!(allocs[2], 5 * tensor_count, "warm-up allocations drifted: {allocs:?}");
        assert_eq!(
            allocs.last(),
            allocs.get(2),
            "steady-state streamed rounds allocated fresh buffers: {allocs:?}"
        );
        // And the wire gauge shows streaming held only chunk-sized
        // payloads while doing it.
        assert!(
            ctrl.peak_wire_ingest_bytes() <= chunk,
            "streamed ingest held {} wire bytes for {chunk}-byte chunks",
            ctrl.peak_wire_ingest_bytes()
        );
    }

    #[test]
    fn quorum_wait_closes_at_the_cut_and_reports_missing() {
        let ctrl = Controller::new(env(), None).unwrap();
        ctrl.ship_model(model(1));
        ctrl.open_round(1, &["a".into(), "b".into(), "c".into()]);
        let mp = ModelProto::from_model(&model(2), DType::F32, ByteOrder::Little);
        for id in ["a", "b"] {
            ctrl.handle(Message::MarkTaskCompleted {
                task_id: 1,
                learner_id: id.into(),
                model: mp.clone(),
                meta: TaskMeta { num_samples: 10, ..Default::default() },
            });
        }
        // Quorum 2/3 is already met: returns without waiting for `c`
        // (the long timeout proves we did not sit in it).
        let sw = Stopwatch::start();
        let outcome = ctrl.wait_round_quorum(Duration::from_secs(30), 0.66);
        assert!(sw.elapsed() < Duration::from_secs(5));
        assert_eq!(outcome.arrived, vec!["a".to_string(), "b".to_string()]);
        assert_eq!(outcome.missing, vec!["c".to_string()]);
        // The missing learner's failure can feed the pacing history.
        ctrl.pacing().observe_failure("c");
        assert_eq!(ctrl.pacing().profile("c").unwrap().failures(), 1);
    }

    #[test]
    fn quorum_aggregate_is_exact_reweighted_subset() {
        // Property (several seeds): a deadline-quorum round's aggregate
        // is bitwise identical to FedAvg over exactly the learners that
        // met the cut, reweighted by their sample counts — learners
        // that missed the deadline contribute nothing.
        for seed in 0..5u64 {
            let mut e = env();
            e.quorum_fraction = 0.5;
            let quorum_ctrl = Controller::new(e, None).unwrap();
            let direct_ctrl = Controller::new(env(), None).unwrap();
            quorum_ctrl.ship_model(model(seed));
            direct_ctrl.ship_model(model(seed));

            let all = ["a", "b", "c", "d"];
            let expecting: Vec<String> = all.iter().map(|s| s.to_string()).collect();
            quorum_ctrl.open_round(1, &expecting);
            // Only half the fleet completes before the cut.
            let arrived = &all[..2];
            for (i, id) in arrived.iter().enumerate() {
                let mp = ModelProto::from_model(
                    &model(100 + seed * 10 + i as u64),
                    DType::F32,
                    ByteOrder::Little,
                );
                let meta = TaskMeta { num_samples: 10 + 7 * i, ..Default::default() };
                quorum_ctrl.handle(Message::MarkTaskCompleted {
                    task_id: 1,
                    learner_id: id.to_string(),
                    model: mp.clone(),
                    meta: meta.clone(),
                });
                direct_ctrl.open_round(1, &[id.to_string()]);
                direct_ctrl.handle(Message::MarkTaskCompleted {
                    task_id: 1,
                    learner_id: id.to_string(),
                    model: mp,
                    meta,
                });
                direct_ctrl.wait_round_completions(Duration::from_secs(1));
            }
            let outcome = quorum_ctrl.wait_round_quorum(Duration::from_secs(5), 0.5);
            assert_eq!(outcome.arrived.len(), 2, "seed {seed}");
            let q = quorum_ctrl.aggregate_from_store(&outcome.arrived, 1).unwrap();
            let ids: Vec<String> = arrived.iter().map(|s| s.to_string()).collect();
            let d = direct_ctrl.aggregate_from_store(&ids, 1).unwrap();
            assert_eq!(*q, *d, "seed {seed}: quorum aggregate != reweighted subset");
        }
    }

    #[test]
    fn late_completion_folds_through_staleness_path() {
        let mut e = env();
        e.quorum_fraction = 0.5;
        let ctrl = Controller::new(e, None).unwrap();
        ctrl.ship_model(model(1));
        ctrl.open_round(1, &["a".into(), "b".into()]);
        let fast = model(2);
        ctrl.handle(Message::MarkTaskCompleted {
            task_id: 1,
            learner_id: "a".into(),
            model: ModelProto::from_model(&fast, DType::F32, ByteOrder::Little),
            meta: TaskMeta { num_samples: 10, ..Default::default() },
        });
        let outcome = ctrl.wait_round_quorum(Duration::from_secs(5), 0.5);
        assert_eq!(outcome.arrived, vec!["a".to_string()]);
        let aggregated = ctrl.aggregate_from_store(&outcome.arrived, 1).unwrap();
        assert_eq!(ctrl.late_folds(), 0);

        // `b` finishes after the round closed: folded via the async
        // staleness mix, not dropped. Dispatched at round 1, community
        // now at round 1 → staleness 0 → w = 0.5.
        let slow = model(3);
        let reply = ctrl.handle(Message::MarkTaskCompleted {
            task_id: 1,
            learner_id: "b".into(),
            model: ModelProto::from_model(&slow, DType::F32, ByteOrder::Little),
            meta: TaskMeta { num_samples: 10, ..Default::default() },
        });
        assert!(matches!(reply, Message::Ack { ok: true, .. }), "{reply:?}");
        assert_eq!(ctrl.late_folds(), 1);
        let (community, round) = ctrl.community().unwrap();
        // The sync round counter is untouched by the fold…
        assert_eq!(round, 1);
        // …and the mix is bitwise the staleness formula's output.
        let expect = aggregation::WeightedSum::compute(
            &[aggregated, Arc::new(slow.clone())],
            &[0.5, 0.5],
            &ctrl.effective_backend(),
        )
        .unwrap();
        assert_eq!(*community, expect);

        // Replays are idempotent: re-sending b's completion (lost ack +
        // reconnect) must not mix the same model a second time — and
        // neither may a replay of a's already-aggregated completion.
        for (id, m) in [("b", &slow), ("a", &fast)] {
            let reply = ctrl.handle(Message::MarkTaskCompleted {
                task_id: 1,
                learner_id: id.to_string(),
                model: ModelProto::from_model(m, DType::F32, ByteOrder::Little),
                meta: TaskMeta { num_samples: 10, ..Default::default() },
            });
            assert!(matches!(reply, Message::Ack { ok: true, .. }), "{reply:?}");
        }
        assert_eq!(ctrl.late_folds(), 1, "replayed completions re-folded");
        let (community_after, _) = ctrl.community().unwrap();
        assert!(Arc::ptr_eq(&community, &community_after));

        // A fabricated FUTURE task id (beyond anything dispatched to
        // b) must not fold either — it would zero the staleness
        // discount and inject at full weight.
        ctrl.handle(Message::MarkTaskCompleted {
            task_id: 10_000,
            learner_id: "b".into(),
            model: ModelProto::from_model(&model(9), DType::F32, ByteOrder::Little),
            meta: TaskMeta { num_samples: 10, ..Default::default() },
        });
        assert_eq!(ctrl.late_folds(), 1, "future task id was folded");
        let (community_after, _) = ctrl.community().unwrap();
        assert!(Arc::ptr_eq(&community, &community_after));
    }

    #[test]
    fn late_fold_discounts_by_the_trained_round_not_dispatch_round() {
        // `b` trains for round 1 but its completion lands only after
        // round 2 aggregated AND b was re-selected for round 3 (so its
        // dispatch_round entry points at the newer task). The staleness
        // basis must be the completion's own round (1): staleness =
        // 2 − 1 = 1 ⇒ w = 0.5 · 2^{-α}.
        let mut e = env();
        e.quorum_fraction = 0.5;
        e.quorum_late_alpha = 1.0;
        let ctrl = Controller::new(e, None).unwrap();
        ctrl.ship_model(model(1));
        let mp = |seed: u64| ModelProto::from_model(&model(seed), DType::F32, ByteOrder::Little);
        // Round 1: a completes, b misses the cut.
        ctrl.open_round(1, &["a".into(), "b".into()]);
        ctrl.handle(Message::MarkTaskCompleted {
            task_id: 1,
            learner_id: "a".into(),
            model: mp(2),
            meta: TaskMeta { num_samples: 10, ..Default::default() },
        });
        let o1 = ctrl.wait_round_quorum(Duration::from_secs(5), 0.5);
        ctrl.aggregate_from_store(&o1.arrived, 1).unwrap();
        // Round 2: a again; aggregate → community_round = 2.
        ctrl.open_round(2, &["a".into()]);
        ctrl.handle(Message::MarkTaskCompleted {
            task_id: 2,
            learner_id: "a".into(),
            model: mp(3),
            meta: TaskMeta { num_samples: 10, ..Default::default() },
        });
        let o2 = ctrl.wait_round_quorum(Duration::from_secs(5), 0.5);
        ctrl.aggregate_from_store(&o2.arrived, 2).unwrap();
        // Round 3 opens and re-selects b, overwriting dispatch_round[b].
        ctrl.open_round(3, &["a".into(), "b".into()]);
        let (before, _) = ctrl.community().unwrap();
        // b's ROUND-1 completion finally arrives.
        let stale_model = model(4);
        ctrl.handle(Message::MarkTaskCompleted {
            task_id: 1,
            learner_id: "b".into(),
            model: ModelProto::from_model(&stale_model, DType::F32, ByteOrder::Little),
            meta: TaskMeta { num_samples: 10, ..Default::default() },
        });
        assert_eq!(ctrl.late_folds(), 1);
        // staleness 1, α = 1 ⇒ w = 0.5 · 2⁻¹ = 0.25 (computed through
        // the same powf expression as the fold, for bitwise equality).
        let w = (1.0f64 + 1.0).powf(-1.0) * 0.5;
        let expect = aggregation::WeightedSum::compute(
            &[before, Arc::new(stale_model)],
            &[1.0 - w, w],
            &ctrl.effective_backend(),
        )
        .unwrap();
        let (community, _) = ctrl.community().unwrap();
        assert_eq!(*community, expect);
    }

    #[test]
    fn stale_completion_does_not_tick_the_next_rounds_barrier() {
        // A straggler's completion from a closed quorum round arrives
        // while the NEXT round is open and expecting the same learner:
        // it must take the late-fold path (its task id names the old
        // round), not masquerade as the new round's arrival.
        let mut e = env();
        e.quorum_fraction = 0.5;
        let ctrl = Controller::new(e, None).unwrap();
        ctrl.ship_model(model(1));
        ctrl.open_round(1, &["a".into(), "b".into()]);
        ctrl.handle(Message::MarkTaskCompleted {
            task_id: 1,
            learner_id: "a".into(),
            model: ModelProto::from_model(&model(2), DType::F32, ByteOrder::Little),
            meta: TaskMeta { num_samples: 10, ..Default::default() },
        });
        let outcome = ctrl.wait_round_quorum(Duration::from_secs(5), 0.5);
        ctrl.aggregate_from_store(&outcome.arrived, 1).unwrap();
        // Round 2 opens, also expecting `b`…
        ctrl.open_round(2, &["a".into(), "b".into()]);
        // …and b's ROUND-1 completion lands now.
        ctrl.handle(Message::MarkTaskCompleted {
            task_id: 1,
            learner_id: "b".into(),
            model: ModelProto::from_model(&model(3), DType::F32, ByteOrder::Little),
            meta: TaskMeta { num_samples: 10, ..Default::default() },
        });
        assert_eq!(ctrl.late_folds(), 1, "stale completion should late-fold");
        // The round-2 barrier has NOT ticked for b: only a fresh
        // round-2 completion counts.
        ctrl.handle(Message::MarkTaskCompleted {
            task_id: 2,
            learner_id: "b".into(),
            model: ModelProto::from_model(&model(4), DType::F32, ByteOrder::Little),
            meta: TaskMeta { num_samples: 10, ..Default::default() },
        });
        let outcome = ctrl.wait_round_quorum(Duration::from_secs(5), 0.5);
        assert_eq!(outcome.arrived, vec!["b".to_string()]);
        assert_eq!(ctrl.late_folds(), 1);
        // Round 2 aggregates b's FRESH model: a replay of the stale
        // round-1 completion (landing right before aggregation) was
        // refused at the store too, so it cannot become the round's
        // aggregation input.
        ctrl.handle(Message::MarkTaskCompleted {
            task_id: 1,
            learner_id: "b".into(),
            model: ModelProto::from_model(&model(3), DType::F32, ByteOrder::Little),
            meta: TaskMeta { num_samples: 10, ..Default::default() },
        });
        let aggregated = ctrl.aggregate_from_store(&outcome.arrived, 2).unwrap();
        assert_eq!(*aggregated, model(4), "stale replay clobbered the stored fresh model");
    }

    #[test]
    fn deregistration_releases_an_open_round_barrier() {
        let ctrl = Controller::new(env(), None).unwrap();
        ctrl.ship_model(model(1));
        ctrl.register_learner("a", "inproc://a", 10);
        ctrl.register_learner("b", "inproc://b", 10);
        ctrl.open_round(1, &["a".into(), "b".into()]);
        ctrl.handle(Message::MarkTaskCompleted {
            task_id: 1,
            learner_id: "a".into(),
            model: ModelProto::from_model(&model(2), DType::F32, ByteOrder::Little),
            meta: TaskMeta { num_samples: 10, ..Default::default() },
        });
        // `b` departs mid-round: the barrier must re-target to just the
        // arrived learner instead of burning the full timeout, and `b`
        // must not be reported missing (no failure ghost in pacing).
        assert!(ctrl.deregister_learner("b"));
        let sw = Stopwatch::start();
        let outcome = ctrl.wait_round_quorum(Duration::from_secs(30), 1.0);
        assert!(sw.elapsed() < Duration::from_secs(5), "barrier waited on departed learner");
        assert_eq!(outcome.arrived, vec!["a".to_string()]);
        assert!(outcome.missing.is_empty());
        assert!(ctrl.pacing().profile("b").is_none());
    }

    #[test]
    fn late_completion_dropped_without_quorum_config() {
        // Classic rounds (quorum 1.0): a dispatched learner's
        // completion landing after the round timed out is observed for
        // its pacing profile but neither folded nor stored (it could
        // only clobber fresher aggregation inputs) — and a completion
        // from a never-dispatched peer is refused outright.
        let ctrl = Controller::new(env(), None).unwrap();
        ctrl.ship_model(model(1));
        ctrl.open_round(1, &["a".into(), "b".into()]);
        ctrl.handle(Message::MarkTaskCompleted {
            task_id: 1,
            learner_id: "a".into(),
            model: ModelProto::from_model(&model(2), DType::F32, ByteOrder::Little),
            meta: TaskMeta { num_samples: 10, ..Default::default() },
        });
        // `b` misses the (tiny) timeout; the round closes without it.
        let arrived = ctrl.wait_round_completions(Duration::from_millis(50));
        assert_eq!(arrived, vec!["a".to_string()]);
        let aggregated = ctrl.aggregate_from_store(&arrived, 1).unwrap();
        // b's straggler completion now lands: profiled, not folded.
        let reply = ctrl.handle(Message::MarkTaskCompleted {
            task_id: 1,
            learner_id: "b".into(),
            model: ModelProto::from_model(&model(3), DType::F32, ByteOrder::Little),
            meta: TaskMeta { num_samples: 10, completed_steps: 5, ..Default::default() },
        });
        assert!(matches!(reply, Message::Ack { ok: true, .. }), "{reply:?}");
        assert_eq!(ctrl.late_folds(), 0);
        assert_eq!(ctrl.pacing().profile("b").unwrap().completions(), 1);
        // Never-dispatched peer: refused before any state changes.
        ctrl.handle(Message::MarkTaskCompleted {
            task_id: 1,
            learner_id: "zzz".into(),
            model: ModelProto::from_model(&model(4), DType::F32, ByteOrder::Little),
            meta: TaskMeta { num_samples: 10, ..Default::default() },
        });
        assert_eq!(ctrl.late_folds(), 0);
        assert!(ctrl.pacing().profile("zzz").is_none());
        let (community, _) = ctrl.community().unwrap();
        assert!(Arc::ptr_eq(&community, &aggregated));
    }

    #[test]
    fn completion_telemetry_feeds_pacing_profiles() {
        let ctrl = Controller::new(env(), None).unwrap();
        ctrl.ship_model(model(1));
        ctrl.open_round(1, &["a".into()]);
        ctrl.handle(Message::MarkTaskCompleted {
            task_id: 1,
            learner_id: "a".into(),
            model: ModelProto::from_model(&model(2), DType::F32, ByteOrder::Little),
            meta: TaskMeta {
                num_samples: 10,
                completed_steps: 50,
                steps_per_sec: 40.0,
                train_wall_time_us: 1_250_000,
                ..Default::default()
            },
        });
        let p = ctrl.pacing().profile("a").expect("profile created");
        assert_eq!(p.completions(), 1);
        assert!((p.steps_per_sec().unwrap() - 40.0).abs() < 1e-9);
        // open_round stamped the send time, so the completion produced
        // an RTT sample.
        assert!(p.rtt().is_some());
    }

    #[test]
    fn deregister_drops_learner_state_via_service() {
        let ctrl = Controller::new(env(), None).unwrap();
        ctrl.register_learner("a", "inproc://a", 10);
        ctrl.register_learner("b", "inproc://b", 10);
        ctrl.open_round(1, &["a".into(), "b".into()]);
        ctrl.pacing().observe_failure("a");
        ctrl.learner_bases.lock().unwrap().insert("a", 1, Arc::new(model(5)));
        let reply = ctrl.handle(Message::Deregister { learner_id: "a".into() });
        assert_eq!(reply, Message::Ack { task_id: 0, ok: true });
        assert_eq!(ctrl.learner_count(), 1);
        assert!(ctrl.pacing().profile("a").is_none());
        assert!(ctrl.learner_bases.lock().unwrap().get("a").is_none());
        // Unknown learner → typed NotFound.
        match ctrl.handle(Message::Deregister { learner_id: "a".into() }) {
            Message::Error { code, .. } => assert_eq!(code, ErrorCode::NotFound),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn learner_base_map_is_capped_by_controller() {
        let ctrl = Controller::new(env(), None).unwrap();
        ctrl.set_learner_base_cap(2);
        let mut bases = ctrl.learner_bases.lock().unwrap();
        for i in 0..6u64 {
            bases.insert(&format!("l{i}"), i, Arc::new(model(50 + i)));
        }
        assert!(bases.distinct_models() <= 2, "{}", bases.distinct_models());
        // The most recent entries survive.
        assert!(bases.get("l5").is_some());
        drop(bases);
        // Sync-style aliasing: many learners, one model — no eviction.
        ctrl.set_learner_base_cap(2);
        let shared = Arc::new(model(9));
        let mut bases = ctrl.learner_bases.lock().unwrap();
        for i in 0..10u64 {
            bases.insert(&format!("l{i}"), 1, Arc::clone(&shared));
        }
        assert_eq!(bases.len(), 10);
        assert_eq!(bases.distinct_models(), 1);
    }

    #[test]
    fn aggregate_result_is_shared_not_copied() {
        let ctrl = Controller::new(env(), None).unwrap();
        ctrl.ship_model(model(1));
        ctrl.open_round(1, &["a".into()]);
        ctrl.handle(Message::MarkTaskCompleted {
            task_id: 1,
            learner_id: "a".into(),
            model: ModelProto::from_model(&model(2), DType::F32, ByteOrder::Little),
            meta: TaskMeta { num_samples: 10, ..Default::default() },
        });
        let arrived = ctrl.wait_round_completions(Duration::from_secs(1));
        let new_model = ctrl.aggregate_from_store(&arrived, 1).unwrap();
        let (community, _) = ctrl.community().unwrap();
        // Same allocation: the slot and the return value alias one model.
        assert!(Arc::ptr_eq(&new_model, &community));
    }

    #[test]
    fn async_replayed_completion_mixes_once() {
        let e = FederationEnv::builder("async-replay")
            .learners(2)
            .model(ModelSpec::mlp(4, 2, 8))
            .protocol(Protocol::Asynchronous { staleness_alpha: 1.0 })
            .build();
        let ctrl = Controller::new(e, None).unwrap();
        ctrl.ship_model(model(1));
        let msg = Message::MarkTaskCompleted {
            task_id: 1,
            learner_id: "a".into(),
            model: ModelProto::from_model(&model(2), DType::F32, ByteOrder::Little),
            meta: TaskMeta { num_samples: 10, ..Default::default() },
        };
        assert!(matches!(ctrl.handle(msg.clone()), Message::Ack { ok: true, .. }));
        assert_eq!(ctrl.async_updates(), 1);
        let (community, _) = ctrl.community().unwrap();
        // A retransmit after a lost ack is acked idempotently: no
        // second mix, no second community update.
        assert!(matches!(ctrl.handle(msg), Message::Ack { ok: true, .. }));
        assert_eq!(ctrl.async_updates(), 1);
        let (after, _) = ctrl.community().unwrap();
        assert!(Arc::ptr_eq(&community, &after));
    }

    #[test]
    fn async_mix_discounts_stale_updates() {
        let e = FederationEnv::builder("async-test")
            .learners(2)
            .model(ModelSpec::mlp(4, 2, 8))
            .protocol(Protocol::Asynchronous { staleness_alpha: 1.0 })
            .build();
        let ctrl = Controller::new(e, None).unwrap();
        let base = model(1);
        ctrl.ship_model(base.clone());
        let update = model(2);
        let mp = ModelProto::from_model(&update, DType::F32, ByteOrder::Little);
        // Fresh update (staleness 0): w = 0.5.
        ctrl.handle(Message::MarkTaskCompleted {
            task_id: 1,
            learner_id: "a".into(),
            model: mp.clone(),
            meta: TaskMeta { num_samples: 100, ..Default::default() },
        });
        let (c1, r1) = ctrl.community().unwrap();
        assert_eq!(r1, 1);
        let expect = 0.5 * base.tensors[0].data[0] + 0.5 * update.tensors[0].data[0];
        assert!((c1.tensors[0].data[0] - expect).abs() < 1e-5);
        assert_eq!(ctrl.async_updates(), 1);
    }

    /// Drive a model through the streaming trio directly against
    /// `handle()` (no transport), via the REAL sender walk
    /// (`proto::client::stream_model_with`) so the test exercises the
    /// exact bytes/digest/seq the production client produces.
    fn stream_via_handle(
        ctrl: &Controller,
        purpose: StreamPurpose,
        task_id: u64,
        learner_id: &str,
        m: &TensorModel,
        meta: TaskMeta,
        chunk: usize,
    ) -> crate::proto::client::RpcResult<()> {
        let spec = TaskSpec::default();
        let send =
            StreamSend::f32(purpose, task_id, 0, learner_id, m, &meta, &spec, chunk);
        crate::proto::client::stream_model_with(&mut |msg| Ok(ctrl.handle(msg)), &send)
            .map(|_| ())
    }

    #[test]
    fn streamed_round_is_bitwise_identical_to_one_shot() {
        // Same federation driven twice: learner uploads as one-shot
        // MarkTaskCompleted vs. as chunked streams (with a chunk size
        // that splits elements and tensors arbitrarily). The aggregated
        // community models must be bitwise identical.
        let one_shot = Controller::new(env(), None).unwrap();
        let streamed = Controller::new(env(), None).unwrap();
        one_shot.ship_model(model(1));
        streamed.ship_model(model(1));
        for ctrl in [&one_shot, &streamed] {
            ctrl.open_round(1, &["a".into(), "b".into()]);
        }
        for (i, id) in ["a", "b"].into_iter().enumerate() {
            let m = model(40 + i as u64);
            let meta = TaskMeta { num_samples: 10 + i, ..Default::default() };
            let reply = one_shot.handle(Message::MarkTaskCompleted {
                task_id: 1,
                learner_id: id.into(),
                model: ModelProto::from_model(&m, DType::F32, ByteOrder::Little),
                meta: meta.clone(),
            });
            assert!(matches!(reply, Message::Ack { ok: true, .. }), "{reply:?}");
            // 13-byte chunks: split mid-element and across tensor
            // boundaries on purpose (the unclamped sender walk makes
            // sub-MIN_CHUNK sizes reachable).
            stream_via_handle(&streamed, StreamPurpose::TaskCompletion, 1, id, &m, meta, 13)
                .unwrap();
        }
        for ctrl in [&one_shot, &streamed] {
            let arrived = ctrl.wait_round_completions(Duration::from_secs(1));
            assert_eq!(arrived.len(), 2);
            ctrl.aggregate_from_store(&arrived, 1).unwrap();
        }
        let (a, _) = one_shot.community().unwrap();
        let (b, _) = streamed.community().unwrap();
        assert_eq!(*a, *b, "streamed aggregation diverged from one-shot");
        assert_eq!(streamed.open_streams(), 0);
    }

    #[test]
    fn streamed_ship_model_installs_community() {
        let ctrl = Controller::new(env(), None).unwrap();
        let m = model(9);
        stream_via_handle(&ctrl, StreamPurpose::ShipModel, 0, "", &m, TaskMeta::default(), 32)
            .unwrap();
        let (community, _) = ctrl.community().unwrap();
        assert_eq!(*community, m);
    }

    #[test]
    fn stream_protocol_violations_are_typed_errors() {
        let ctrl = Controller::new(env(), None).unwrap();
        // Chunk/end for a stream that was never opened.
        for msg in [
            Message::ModelChunk { stream_id: 77, seq: 0, bytes: vec![0; 4] },
            Message::ModelStreamEnd { stream_id: 77, digest: 0 },
        ] {
            match ctrl.handle(msg) {
                Message::Error { code, .. } => assert_eq!(code, ErrorCode::StreamProtocol),
                other => panic!("unexpected {other:?}"),
            }
        }
        let m = model(3);
        let begin = |stream_id: u64| Message::ModelStreamBegin {
            stream_id,
            task_id: 1,
            round: 0,
            purpose: StreamPurpose::TaskCompletion,
            learner_id: "a".into(),
            codec: CodecId::F32,
            base_round: 0,
            layout: TensorLayoutProto::f32_layout_of(&m),
            meta: TaskMeta::default(),
            spec: TaskSpec::default(),
        };
        // Duplicate stream id.
        assert!(matches!(ctrl.handle(begin(5)), Message::Ack { ok: true, .. }));
        match ctrl.handle(begin(5)) {
            Message::Error { code, .. } => assert_eq!(code, ErrorCode::StreamProtocol),
            other => panic!("unexpected {other:?}"),
        }
        // Out-of-order chunk kills the stream…
        match ctrl.handle(Message::ModelChunk { stream_id: 5, seq: 3, bytes: vec![0; 4] }) {
            Message::Error { code, .. } => assert_eq!(code, ErrorCode::StreamProtocol),
            other => panic!("unexpected {other:?}"),
        }
        // …so the follow-up end sees an unknown stream.
        assert!(matches!(
            ctrl.handle(Message::ModelStreamEnd { stream_id: 5, digest: 0 }),
            Message::Error { .. }
        ));
        assert_eq!(ctrl.open_streams(), 0);
        // Truncated stream: end before all bytes arrived.
        assert!(matches!(ctrl.handle(begin(6)), Message::Ack { ok: true, .. }));
        match ctrl.handle(Message::ModelStreamEnd { stream_id: 6, digest: FNV64_INIT }) {
            Message::Error { code, detail } => {
                assert_eq!(code, ErrorCode::StreamProtocol);
                assert!(detail.contains("truncated"), "{detail}");
            }
            other => panic!("unexpected {other:?}"),
        }
        // Digest mismatch.
        assert!(matches!(ctrl.handle(begin(8)), Message::Ack { ok: true, .. }));
        let mut seq = 0u64;
        for t in &m.tensors {
            let bytes = t.encode_data(DType::F32, ByteOrder::Little);
            ctrl.handle(Message::ModelChunk { stream_id: 8, seq, bytes });
            seq += 1;
        }
        match ctrl.handle(Message::ModelStreamEnd { stream_id: 8, digest: 0xBAD }) {
            Message::Error { code, detail } => {
                assert_eq!(code, ErrorCode::StreamProtocol);
                assert!(detail.contains("digest"), "{detail}");
            }
            other => panic!("unexpected {other:?}"),
        }
        // None of this touched round/community state.
        assert!(ctrl.community().is_none());
        assert_eq!(ctrl.open_streams(), 0);
    }

    #[test]
    fn one_shot_ingest_holds_whole_model_streamed_holds_chunks() {
        let m = model(2);
        let model_bytes = m.byte_size_f32();
        let one_shot = Controller::new(env(), None).unwrap();
        one_shot.ship_model(model(1));
        one_shot.handle(Message::MarkTaskCompleted {
            task_id: 1,
            learner_id: "a".into(),
            model: ModelProto::from_model(&m, DType::F32, ByteOrder::Little),
            meta: TaskMeta::default(),
        });
        assert!(one_shot.peak_wire_ingest_bytes() >= model_bytes);

        let streamed = Controller::new(env(), None).unwrap();
        streamed.ship_model(model(1));
        let chunk = 16;
        stream_via_handle(
            &streamed,
            StreamPurpose::TaskCompletion,
            1,
            "a",
            &m,
            TaskMeta::default(),
            chunk,
        )
        .unwrap();
        assert!(
            streamed.peak_wire_ingest_bytes() <= chunk,
            "streamed ingest held {} wire bytes for a {chunk}-byte chunk",
            streamed.peak_wire_ingest_bytes()
        );
    }

    #[test]
    fn hello_handshake_checks_version_and_negotiates_codecs() {
        let ctrl = Controller::new(env(), None).unwrap();
        let offered = vec![CodecId::Delta, CodecId::F32];
        match ctrl.handle(Message::Hello { proto_version: PROTO_VERSION, codecs: offered }) {
            Message::HelloAck { proto_version, component, codecs } => {
                assert_eq!(proto_version, PROTO_VERSION);
                assert_eq!(component, "controller");
                // Accepted = intersection, in our preference order.
                assert_eq!(codecs, vec![CodecId::F32, CodecId::Delta]);
            }
            other => panic!("unexpected {other:?}"),
        }
        match ctrl.handle(Message::Hello { proto_version: 999, codecs: Vec::new() }) {
            Message::Error { code, .. } => assert_eq!(code, ErrorCode::VersionMismatch),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn shutdown_rejects_further_messages() {
        let ctrl = Controller::new(env(), None).unwrap();
        assert_eq!(ctrl.handle(Message::Shutdown), Message::Ack { task_id: 0, ok: true });
        assert!(matches!(
            ctrl.handle(Message::GetModel),
            Message::Error { .. }
        ));
        assert!(ctrl.is_shutdown());
    }

    #[test]
    fn heartbeat_ack_reports_real_component_state() {
        let ctrl = Controller::new(env(), None).unwrap();
        match ctrl.handle(Message::Heartbeat { from: "driver".into() }) {
            Message::HeartbeatAck { component, healthy, health } => {
                assert_eq!(component, "controller");
                assert!(healthy);
                assert_eq!(health, HealthProbe::default());
            }
            other => panic!("unexpected {other:?}"),
        }
        // An open round and a retry give-up surface in the probe; the
        // give-up flips the ack to degraded.
        ctrl.ship_model(model(1));
        ctrl.open_round(1, &["a".into()]);
        ctrl.retry_give_ups.incr();
        match ctrl.handle(Message::Heartbeat { from: "driver".into() }) {
            Message::HeartbeatAck { healthy, health, .. } => {
                assert!(!healthy, "retry give-ups must degrade the ack");
                assert_eq!(health.open_rounds, 1);
                assert_eq!(health.retry_give_ups, 1);
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn secure_over_tcp_rejected() {
        let mut e = env();
        e.secure = SecureSpec::Masking;
        e.transport = crate::config::TransportKind::Tcp { base_port: 45000 };
        assert!(Controller::new(e, None).is_err());
    }
}
