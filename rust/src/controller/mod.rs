//! The federation controller — "the first-class citizen of the system".
//!
//! Owns the community model, the learner registry, the model store, the
//! aggregation rule/backend, and the round lifecycle state. It is exposed
//! to the network as a [`Service`] handling the Appendix-B RPCs
//! (`Register`, `MarkTaskCompleted`, heartbeats, …); the round-driving
//! logic lives in [`scheduling`] (sync / semi-sync / async protocols).

pub mod aggregation;
pub mod scheduling;
pub mod selector;
pub mod store;

use crate::config::{FederationEnv, Protocol, SecureSpec};
use crate::metrics::{FedOp, OpMetrics};
use crate::net::{ClientConn, Psk, Service};
use crate::proto::{Message, ModelProto, TaskMeta};
use crate::tensor::{ByteOrder, DType, TensorModel};
use crate::util::{log_debug, log_info, Stopwatch, ThreadPool};
use aggregation::{Backend, Contribution};
use anyhow::{bail, Context, Result};
use selector::Selector;
use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;
use store::{ModelStore, StoredModel};

/// A registered learner as seen by the controller.
pub struct LearnerHandle {
    pub id: String,
    pub endpoint: String,
    pub num_samples: usize,
    pub index: usize,
    conn: Mutex<Option<Box<dyn ClientConn>>>,
}

impl LearnerHandle {
    pub fn new(id: String, endpoint: String, num_samples: usize, index: usize) -> LearnerHandle {
        LearnerHandle { id, endpoint, num_samples, index, conn: Mutex::new(None) }
    }

    /// RPC to this learner, (re)connecting lazily. The per-learner lock
    /// serializes concurrent calls onto one connection.
    pub fn rpc(&self, psk: Psk, msg: &Message) -> Result<Message> {
        self.rpc_timed(psk, msg, std::time::Instant::now()).map(|(m, _)| m)
    }

    /// RPC that also reports *when* (relative to `origin`) the send
    /// (dispatch) phase finished, separate from the reply wait.
    pub fn rpc_timed(
        &self,
        psk: Psk,
        msg: &Message,
        origin: std::time::Instant,
    ) -> Result<(Message, Duration)> {
        self.rpc_inner(psk, RawOrMsg::Msg(msg), origin)
    }

    /// RPC with pre-encoded request bytes (broadcast fast path: the bytes
    /// are shared across all learners of a round — §Perf).
    pub fn rpc_raw_timed(
        &self,
        psk: Psk,
        bytes: &[u8],
        origin: std::time::Instant,
    ) -> Result<(Message, Duration)> {
        self.rpc_inner(psk, RawOrMsg::Raw(bytes), origin)
    }

    fn rpc_inner(
        &self,
        psk: Psk,
        req: RawOrMsg<'_>,
        origin: std::time::Instant,
    ) -> Result<(Message, Duration)> {
        let mut guard = self.conn.lock().unwrap();
        if guard.is_none() {
            *guard = Some(
                crate::net::connect(&self.endpoint, psk)
                    .with_context(|| format!("connecting to learner {}", self.id))?,
            );
        }
        let conn = guard.as_mut().unwrap();
        let send_res = match req {
            RawOrMsg::Msg(m) => conn.send(m),
            RawOrMsg::Raw(b) => conn.send_raw(b),
        };
        let sent_at = origin.elapsed();
        let result = send_res.and_then(|_| conn.recv());
        match result {
            Ok(reply) => Ok((reply, sent_at)),
            Err(e) => {
                *guard = None; // force reconnect next time
                Err(e)
            }
        }
    }
}

enum RawOrMsg<'a> {
    Msg(&'a Message),
    Raw(&'a [u8]),
}

/// Completion record delivered by `MarkTaskCompleted`.
struct RoundState {
    #[allow(dead_code)]
    round: u64,
    expecting: HashSet<String>,
    arrived: Vec<String>,
}

struct CtrlState {
    /// Community model, shared by pointer: schedulers snapshot it, the
    /// store hands back `Arc`s, and aggregation reads through them — the
    /// controller never deep-copies a model on the hot path.
    community: Option<Arc<TensorModel>>,
    community_round: u64,
    rule: Box<dyn aggregation::AggregationRule>,
    store: Box<dyn ModelStore>,
    learners: Vec<Arc<LearnerHandle>>,
    last_participation: HashMap<String, u64>,
    /// Round each learner's current task was dispatched at (staleness).
    dispatch_round: HashMap<String, u64>,
    round: Option<RoundState>,
    /// Async protocol: community updates applied so far.
    async_updates: u64,
    /// Async protocol: learners with a task currently in flight.
    outstanding: HashSet<String>,
}

/// Injected XLA aggregation kernel (compiled via the runtime module).
pub use aggregation::XlaAggFn;

/// The federation controller.
pub struct Controller {
    pub env: FederationEnv,
    pub psk: Psk,
    backend: Backend,
    state: Mutex<CtrlState>,
    round_cv: Condvar,
    metrics: Mutex<OpMetrics>,
    dispatch_pool: ThreadPool,
    shutdown: AtomicBool,
    xla_slot: Mutex<Option<XlaAggFn>>,
}

impl Controller {
    pub fn new(env: FederationEnv, psk: Psk) -> Result<Arc<Controller>> {
        env.validate()?;
        if env.secure != SecureSpec::None && !matches!(env.transport, crate::config::TransportKind::InProc) {
            bail!("secure aggregation is only wired for in-process simulation (see DESIGN.md)");
        }
        let backend = Backend::from_spec(&env.aggregation);
        let rule = aggregation::rule_from_spec(&env.aggregation)?;
        let dispatch_threads = env.learners.clamp(1, 16);
        Ok(Arc::new(Controller {
            env,
            psk,
            backend,
            state: Mutex::new(CtrlState {
                community: None,
                community_round: 0,
                rule,
                store: Box::new(store::InMemoryStore::new()),
                learners: Vec::new(),
                last_participation: HashMap::new(),
                dispatch_round: HashMap::new(),
                round: None,
                async_updates: 0,
                outstanding: HashSet::new(),
            }),
            round_cv: Condvar::new(),
            metrics: Mutex::new(OpMetrics::new()),
            dispatch_pool: ThreadPool::new(dispatch_threads),
            shutdown: AtomicBool::new(false),
            xla_slot: Mutex::new(None),
        }))
    }

    /// Replace the model store (e.g. [`store::OnDiskStore`]).
    pub fn set_store(&self, s: Box<dyn ModelStore>) {
        self.state.lock().unwrap().store = s;
    }

    /// Wire the XLA aggregation backend (injected by `runtime` after the
    /// compiled fedavg kernel is loaded; until then the Xla config choice
    /// falls back to Sequential).
    pub fn set_xla_backend(&self, f: XlaAggFn) {
        *self.xla_slot.lock().unwrap() = Some(f);
    }

    /// Effective backend for aggregation (resolves the Xla slot).
    fn effective_backend(&self) -> Backend {
        if self.env.aggregation.backend == crate::config::AggregationBackend::Xla {
            if let Some(f) = self.xla_slot.lock().unwrap().clone() {
                return Backend::Xla(f);
            }
        }
        self.backend.clone()
    }

    pub fn is_shutdown(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst)
    }

    /// Registered learner count.
    pub fn learner_count(&self) -> usize {
        self.state.lock().unwrap().learners.len()
    }

    /// Wait until `n` learners registered (driver startup barrier).
    pub fn wait_for_learners(&self, n: usize, timeout: Duration) -> Result<()> {
        let deadline = std::time::Instant::now() + timeout;
        let mut state = self.state.lock().unwrap();
        while state.learners.len() < n {
            let remaining = deadline
                .checked_duration_since(std::time::Instant::now())
                .ok_or_else(|| anyhow::anyhow!("timeout waiting for {n} learners"))?;
            let (s, _) = self.round_cv.wait_timeout(state, remaining).unwrap();
            state = s;
        }
        Ok(())
    }

    /// Snapshot of the community model (initialized by `ShipModel`).
    /// Returns a shared pointer — no copy. Callers that keep the snapshot
    /// across an aggregation (schedulers) should drop it once serialized
    /// so the controller can recycle the buffers on replacement.
    pub fn community(&self) -> Option<(Arc<TensorModel>, u64)> {
        let s = self.state.lock().unwrap();
        s.community.clone().map(|m| (m, s.community_round))
    }

    /// Set the community model directly (driver-local initialization).
    pub fn ship_model(&self, model: TensorModel) {
        let mut s = self.state.lock().unwrap();
        s.community = Some(Arc::new(model));
        log_info("controller", "community model initialized");
    }

    /// Register a learner directly (in-proc driver path).
    pub fn register_learner(&self, id: &str, endpoint: &str, num_samples: usize) -> usize {
        let mut s = self.state.lock().unwrap();
        let index = s.learners.len();
        s.learners.push(Arc::new(LearnerHandle::new(
            id.to_string(),
            endpoint.to_string(),
            num_samples,
            index,
        )));
        log_debug("controller", &format!("registered learner {id} at {endpoint} (#{index})"));
        self.round_cv.notify_all();
        index
    }

    fn learners_snapshot(&self) -> Vec<Arc<LearnerHandle>> {
        self.state.lock().unwrap().learners.clone()
    }

    /// Metrics snapshot.
    pub fn metrics(&self) -> OpMetrics {
        self.metrics.lock().unwrap().clone()
    }

    pub(crate) fn record(&self, op: FedOp, d: Duration) {
        self.metrics.lock().unwrap().record(op, d);
    }

    // ---- round plumbing used by `scheduling` -------------------------

    /// Open a round: note who we expect and stamp dispatch rounds.
    fn open_round(&self, round: u64, expecting: &[String]) {
        let mut s = self.state.lock().unwrap();
        for id in expecting {
            s.dispatch_round.insert(id.clone(), round);
            s.last_participation.insert(id.clone(), round);
        }
        s.round = Some(RoundState {
            round,
            expecting: expecting.iter().cloned().collect(),
            arrived: Vec::new(),
        });
    }

    /// Block until all expected completions arrived or `timeout` elapsed.
    /// Returns the learner ids that did arrive.
    fn wait_round_completions(&self, timeout: Duration) -> Vec<String> {
        let deadline = std::time::Instant::now() + timeout;
        let mut s = self.state.lock().unwrap();
        loop {
            let done = match &s.round {
                Some(r) => r.arrived.len() >= r.expecting.len(),
                None => true,
            };
            if done {
                break;
            }
            let Some(remaining) = deadline.checked_duration_since(std::time::Instant::now())
            else {
                break;
            };
            let (guard, _) = self.round_cv.wait_timeout(s, remaining).unwrap();
            s = guard;
        }
        let mut arrived = s.round.as_ref().map(|r| r.arrived.clone()).unwrap_or_default();
        s.round = None;
        // Sort so aggregation order (and thus fp rounding) is independent
        // of completion timing — parallel and sequential runs of the same
        // federation produce bitwise-identical community models.
        arrived.sort();
        arrived
    }

    /// Aggregate `learner_ids`' latest stored models into a new community
    /// model (T4–T7). Returns the new model (shared, not copied).
    ///
    /// Hot-path properties: `current` and every selection from the store
    /// are `Arc` clones — no model is deep-copied — and with the chunked
    /// backend the output is written into recycled scratch buffers, so a
    /// steady-state round performs zero O(params) allocation.
    fn aggregate_from_store(&self, learner_ids: &[String], round: u64) -> Result<Arc<TensorModel>> {
        let backend = self.effective_backend();
        let mut s = self.state.lock().unwrap();
        let current = s
            .community
            .clone()
            .ok_or_else(|| anyhow::anyhow!("no community model shipped"))?;
        let selected = s.store.select_latest(learner_ids)?;
        if selected.is_empty() {
            bail!("round {round}: no completed learner models to aggregate");
        }
        let contributions: Vec<Contribution> = selected
            .iter()
            .map(|m| Contribution {
                model: Arc::clone(&m.model),
                weight: m.meta.num_samples.max(1) as f64,
            })
            .collect();
        let new_model = Arc::new(s.rule.aggregate(&current, &contributions, &backend)?);
        let previous = s.community.replace(Arc::clone(&new_model));
        s.community_round = round;
        // Keep only the freshest model per learner (paper's in-memory
        // assumption; lineage stores are opt-in via set_store + evict).
        s.store.evict(1)?;
        drop(s);
        // Release our handles on the outgoing community model, then hand
        // its buffers back to the arena for the next round's output.
        drop(current);
        if let (Some(prev), Some(scratch)) = (previous, backend.scratch()) {
            scratch.reclaim_model(prev);
        }
        if crate::util::logging::enabled(crate::util::logging::LogLevel::Debug) {
            log_debug(
                "controller",
                &format!(
                    "round {round}: community ‖w‖₂ = {:.6}",
                    aggregation::model_l2_norm(&new_model, &backend)
                ),
            );
        }
        Ok(new_model)
    }

    /// Async protocol: mix one completed local model into the community
    /// model immediately, discounted by staleness (Stripelis 2022b).
    fn async_mix(&self, entry: &StoredModel, alpha: f64) -> Result<u64> {
        let backend = self.effective_backend();
        let mut s = self.state.lock().unwrap();
        let current = s
            .community
            .clone()
            .ok_or_else(|| anyhow::anyhow!("no community model shipped"))?;
        let dispatched = s.dispatch_round.get(&entry.learner_id).copied().unwrap_or(0);
        let staleness = s.community_round.saturating_sub(dispatched) as f64;
        let w = (1.0 + staleness).powf(-alpha) * 0.5;
        let models = [Arc::clone(&current), Arc::clone(&entry.model)];
        let coeffs = [1.0 - w, w];
        let mixed =
            Arc::new(aggregation::WeightedSum::compute(&models, &coeffs, &backend)?);
        let previous = s.community.replace(mixed);
        drop(models);
        drop(current);
        if let (Some(prev), Some(scratch)) = (previous, backend.scratch()) {
            scratch.reclaim_model(prev);
        }
        s.community_round += 1;
        s.async_updates += 1;
        let updates = s.async_updates;
        // Next task for this learner is dispatched against the new round,
        // and the learner is idle until the scheduler re-dispatches.
        let community_round = s.community_round;
        s.dispatch_round.insert(entry.learner_id.clone(), community_round);
        s.outstanding.remove(&entry.learner_id);
        Ok(updates)
    }

    /// Number of async community updates applied so far.
    pub fn async_updates(&self) -> u64 {
        self.state.lock().unwrap().async_updates
    }

    /// Async protocol: does this learner need a fresh task?
    pub(crate) fn learner_needs_task(&self, id: &str) -> bool {
        !self.state.lock().unwrap().outstanding.contains(id)
    }

    /// Async protocol: note that a task is in flight for this learner.
    pub(crate) fn mark_task_outstanding(&self, id: &str) {
        self.state.lock().unwrap().outstanding.insert(id.to_string());
    }

    /// Dispatch one message to `targets` concurrently. The message is
    /// serialized ONCE and the same bytes fan out to every learner
    /// (§Perf: dispatch used to re-encode the full model per learner).
    /// Returns `(dispatch_time, per-learner results)` where
    /// `dispatch_time` is the wall-clock until every request had been
    /// submitted (the paper's "task dispatch time"); the results include
    /// the full reply wait. Used for both train (fire-and-forget + Ack)
    /// and eval (blocking reply) dispatches.
    fn broadcast(
        &self,
        targets: &[Arc<LearnerHandle>],
        msg: &Message,
    ) -> (Duration, Vec<(String, Result<Message>)>) {
        let psk = self.psk;
        let origin = std::time::Instant::now();
        let encoded = msg.encode();
        let results = self.dispatch_pool.parallel_map(targets.len(), |i| {
            let h = &targets[i];
            h.rpc_raw_timed(psk, &encoded, origin)
        });
        // Dispatch completes when the slowest send has finished (offsets
        // are measured from `origin`, so bounded-pool queueing delay is
        // included — as it is in every framework the paper measures).
        let dispatch: Duration = results
            .iter()
            .filter_map(|r| r.as_ref().ok().map(|(_, sent_at)| *sent_at))
            .max()
            .unwrap_or(Duration::ZERO);
        let out = targets
            .iter()
            .zip(results)
            .map(|(h, r)| (h.id.clone(), r.map(|(reply, _)| reply)))
            .collect();
        (dispatch, out)
    }

    /// Select round participants per the env's participation policy.
    fn select_participants(&self, rng: &mut crate::util::Rng) -> Vec<Arc<LearnerHandle>> {
        let learners = self.learners_snapshot();
        let ids: Vec<String> = learners.iter().map(|l| l.id.clone()).collect();
        let last = self.state.lock().unwrap().last_participation.clone();
        let chosen = Selector::from_participation(self.env.participation).select(&ids, &last, rng);
        let set: HashSet<&String> = chosen.iter().collect();
        learners.into_iter().filter(|l| set.contains(&l.id)).collect()
    }
}

impl Service for Controller {
    fn handle(&self, msg: Message) -> Message {
        if self.is_shutdown() {
            return Message::Error { detail: "controller is shut down".into() };
        }
        match msg {
            Message::Register { learner_id, host, port, num_samples } => {
                // `host` may be a full endpoint (inproc://… or tcp://…)
                // or a bare hostname + port pair.
                let endpoint = if host.contains("://") {
                    host
                } else {
                    format!("tcp://{host}:{port}")
                };
                let idx = self.register_learner(&learner_id, &endpoint, num_samples);
                Message::RegisterAck { accepted: true, assigned_index: idx }
            }
            Message::ShipModel { model } => match model.to_model() {
                Ok(m) => {
                    self.ship_model(m);
                    Message::Ack { task_id: 0, ok: true }
                }
                Err(e) => Message::Error { detail: format!("bad model: {e:#}") },
            },
            Message::MarkTaskCompleted { task_id, learner_id, model, meta } => {
                match self.on_task_completed(task_id, learner_id, model, meta) {
                    Ok(()) => Message::Ack { task_id, ok: true },
                    Err(e) => Message::Error { detail: format!("{e:#}") },
                }
            }
            Message::Heartbeat { .. } => Message::HeartbeatAck {
                component: "controller".into(),
                healthy: true,
            },
            Message::GetModel => {
                let s = self.state.lock().unwrap();
                match &s.community {
                    Some(m) => Message::ModelReply {
                        model: ModelProto::from_model(m, DType::F32, ByteOrder::Little),
                        round: s.community_round,
                    },
                    None => Message::Error { detail: "no community model".into() },
                }
            }
            Message::Shutdown => {
                self.shutdown.store(true, Ordering::SeqCst);
                self.round_cv.notify_all();
                Message::Ack { task_id: 0, ok: true }
            }
            other => Message::Error { detail: format!("unexpected {}", other.kind()) },
        }
    }
}

impl Controller {
    /// `MarkTaskCompleted` path: store the model (T4–T5) and either tick
    /// the round barrier (sync/semi-sync) or mix immediately (async).
    fn on_task_completed(
        &self,
        _task_id: u64,
        learner_id: String,
        model: ModelProto,
        meta: TaskMeta,
    ) -> Result<()> {
        let sw = Stopwatch::start();
        let decoded = model.to_model()?;
        let decode_time = sw.elapsed();
        self.record(FedOp::Serialization, decode_time);

        let entry = StoredModel {
            learner_id: learner_id.clone(),
            round: self.state.lock().unwrap().community_round,
            meta,
            model: Arc::new(decoded),
        };

        match self.env.protocol {
            Protocol::Asynchronous { staleness_alpha } => {
                let sw = Stopwatch::start();
                // Store (for inspection/metrics parity with sync).
                {
                    let mut s = self.state.lock().unwrap();
                    let insert_sw = Stopwatch::start();
                    s.store.insert(entry.clone())?;
                    s.store.evict(1)?;
                    drop(s);
                    self.record(FedOp::StoreInsert, insert_sw.elapsed());
                }
                self.async_mix(&entry, staleness_alpha)?;
                self.record(FedOp::Aggregation, sw.elapsed());
                self.round_cv.notify_all();
                Ok(())
            }
            _ => {
                let mut s = self.state.lock().unwrap();
                let insert_sw = Stopwatch::start();
                s.store.insert(entry)?;
                let insert_time = insert_sw.elapsed();
                if let Some(r) = s.round.as_mut() {
                    if r.expecting.contains(&learner_id)
                        && !r.arrived.iter().any(|a| a == &learner_id)
                    {
                        r.arrived.push(learner_id);
                    }
                }
                drop(s);
                self.record(FedOp::StoreInsert, insert_time);
                self.round_cv.notify_all();
                Ok(())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{FederationEnv, ModelSpec};
    use crate::util::Rng;

    fn env() -> FederationEnv {
        FederationEnv::builder("ctrl-test")
            .learners(3)
            .model(ModelSpec::mlp(4, 2, 8))
            .build()
    }

    fn model(seed: u64) -> TensorModel {
        let layout = ModelSpec::mlp(4, 2, 8).tensor_layout();
        TensorModel::random_init(&layout, &mut Rng::new(seed))
    }

    #[test]
    fn register_and_ship_via_service() {
        let ctrl = Controller::new(env(), None).unwrap();
        let reply = ctrl.handle(Message::Register {
            learner_id: "l0".into(),
            host: "inproc://l0".into(),
            port: 0,
            num_samples: 100,
        });
        assert_eq!(reply, Message::RegisterAck { accepted: true, assigned_index: 0 });
        assert_eq!(ctrl.learner_count(), 1);

        let m = model(1);
        let reply = ctrl.handle(Message::ShipModel {
            model: ModelProto::from_model(&m, DType::F32, ByteOrder::Little),
        });
        assert_eq!(reply, Message::Ack { task_id: 0, ok: true });
        let (community, round) = ctrl.community().unwrap();
        assert_eq!(round, 0);
        assert!(community.max_abs_diff(&m) == 0.0);
    }

    #[test]
    fn completion_barrier_counts_expected_only() {
        let ctrl = Controller::new(env(), None).unwrap();
        ctrl.ship_model(model(1));
        ctrl.open_round(1, &["a".into(), "b".into()]);
        // Unexpected learner does not tick the barrier.
        let mp = ModelProto::from_model(&model(2), DType::F32, ByteOrder::Little);
        ctrl.handle(Message::MarkTaskCompleted {
            task_id: 1,
            learner_id: "zzz".into(),
            model: mp.clone(),
            meta: TaskMeta { num_samples: 10, ..Default::default() },
        });
        ctrl.handle(Message::MarkTaskCompleted {
            task_id: 1,
            learner_id: "a".into(),
            model: mp.clone(),
            meta: TaskMeta { num_samples: 10, ..Default::default() },
        });
        // Duplicate completion counted once.
        ctrl.handle(Message::MarkTaskCompleted {
            task_id: 1,
            learner_id: "a".into(),
            model: mp.clone(),
            meta: TaskMeta { num_samples: 10, ..Default::default() },
        });
        let arrived = ctrl.wait_round_completions(Duration::from_millis(50));
        assert_eq!(arrived, vec!["a".to_string()]); // timeout path
    }

    #[test]
    fn aggregate_from_store_updates_community() {
        let ctrl = Controller::new(env(), None).unwrap();
        ctrl.ship_model(model(1));
        let mp_a = ModelProto::from_model(&model(2), DType::F32, ByteOrder::Little);
        let mp_b = ModelProto::from_model(&model(3), DType::F32, ByteOrder::Little);
        ctrl.open_round(1, &["a".into(), "b".into()]);
        for (id, mp) in [("a", mp_a), ("b", mp_b)] {
            ctrl.handle(Message::MarkTaskCompleted {
                task_id: 1,
                learner_id: id.into(),
                model: mp,
                meta: TaskMeta { num_samples: 100, ..Default::default() },
            });
        }
        let arrived = ctrl.wait_round_completions(Duration::from_secs(1));
        assert_eq!(arrived.len(), 2);
        let new_model = ctrl.aggregate_from_store(&arrived, 1).unwrap();
        let (community, round) = ctrl.community().unwrap();
        assert_eq!(round, 1);
        assert_eq!(community, new_model);
        // Mean of the two models.
        let expect = 0.5 * model(2).tensors[0].data[0] + 0.5 * model(3).tensors[0].data[0];
        assert!((new_model.tensors[0].data[0] - expect).abs() < 1e-5);
    }

    #[test]
    fn chunked_steady_state_rounds_do_not_allocate_output_buffers() {
        use crate::config::{AggregationBackend, AggregationSpec};
        let mut e = env();
        e.aggregation = AggregationSpec {
            backend: AggregationBackend::Chunked,
            threads: 2,
            ..Default::default()
        };
        let ctrl = Controller::new(e, None).unwrap();
        ctrl.ship_model(model(1));
        let scratch = Arc::clone(ctrl.backend.scratch().expect("chunked backend"));
        let tensor_count = model(1).tensor_count();
        let mut allocs_per_round = Vec::new();
        for round in 1..=5u64 {
            ctrl.open_round(round, &["a".into(), "b".into()]);
            for (i, id) in ["a", "b"].into_iter().enumerate() {
                let m = model(100 + round * 2 + i as u64);
                ctrl.handle(Message::MarkTaskCompleted {
                    task_id: round,
                    learner_id: id.into(),
                    model: ModelProto::from_model(&m, DType::F32, ByteOrder::Little),
                    meta: TaskMeta { num_samples: 10, ..Default::default() },
                });
            }
            let arrived = ctrl.wait_round_completions(Duration::from_secs(1));
            assert_eq!(arrived.len(), 2);
            ctrl.aggregate_from_store(&arrived, round).unwrap();
            allocs_per_round.push(scratch.fresh_allocations());
        }
        // Round 1 pays one buffer per output tensor; every later round
        // reuses the buffers reclaimed from the replaced community model.
        assert_eq!(allocs_per_round[0], tensor_count);
        assert_eq!(
            allocs_per_round.last(),
            allocs_per_round.first(),
            "steady-state rounds allocated output buffers: {allocs_per_round:?}"
        );
    }

    #[test]
    fn aggregate_result_is_shared_not_copied() {
        let ctrl = Controller::new(env(), None).unwrap();
        ctrl.ship_model(model(1));
        ctrl.open_round(1, &["a".into()]);
        ctrl.handle(Message::MarkTaskCompleted {
            task_id: 1,
            learner_id: "a".into(),
            model: ModelProto::from_model(&model(2), DType::F32, ByteOrder::Little),
            meta: TaskMeta { num_samples: 10, ..Default::default() },
        });
        let arrived = ctrl.wait_round_completions(Duration::from_secs(1));
        let new_model = ctrl.aggregate_from_store(&arrived, 1).unwrap();
        let (community, _) = ctrl.community().unwrap();
        // Same allocation: the slot and the return value alias one model.
        assert!(Arc::ptr_eq(&new_model, &community));
    }

    #[test]
    fn async_mix_discounts_stale_updates() {
        let e = FederationEnv::builder("async-test")
            .learners(2)
            .model(ModelSpec::mlp(4, 2, 8))
            .protocol(Protocol::Asynchronous { staleness_alpha: 1.0 })
            .build();
        let ctrl = Controller::new(e, None).unwrap();
        let base = model(1);
        ctrl.ship_model(base.clone());
        let update = model(2);
        let mp = ModelProto::from_model(&update, DType::F32, ByteOrder::Little);
        // Fresh update (staleness 0): w = 0.5.
        ctrl.handle(Message::MarkTaskCompleted {
            task_id: 1,
            learner_id: "a".into(),
            model: mp.clone(),
            meta: TaskMeta { num_samples: 100, ..Default::default() },
        });
        let (c1, r1) = ctrl.community().unwrap();
        assert_eq!(r1, 1);
        let expect = 0.5 * base.tensors[0].data[0] + 0.5 * update.tensors[0].data[0];
        assert!((c1.tensors[0].data[0] - expect).abs() < 1e-5);
        assert_eq!(ctrl.async_updates(), 1);
    }

    #[test]
    fn shutdown_rejects_further_messages() {
        let ctrl = Controller::new(env(), None).unwrap();
        assert_eq!(ctrl.handle(Message::Shutdown), Message::Ack { task_id: 0, ok: true });
        assert!(matches!(
            ctrl.handle(Message::GetModel),
            Message::Error { .. }
        ));
        assert!(ctrl.is_shutdown());
    }

    #[test]
    fn secure_over_tcp_rejected() {
        let mut e = env();
        e.secure = SecureSpec::Masking;
        e.transport = crate::config::TransportKind::Tcp { base_port: 45000 };
        assert!(Controller::new(e, None).is_err());
    }
}
