//! Minimal AES-128 (FIPS 197, encryption only), vendored with the `aes`
//! crate's call surface (`Aes128`, `cipher::{KeyInit, BlockEncrypt}`) so
//! the workspace builds fully offline. Blocks and keys are plain
//! `[u8; 16]`, which the call sites construct via `.into()` exactly as
//! they would a `GenericArray`.
//!
//! The S-box is derived at first use from its definition (multiplicative
//! inverse in GF(2⁸) followed by the affine transform) rather than a
//! transcribed table; the FIPS-197 appendix vector below pins the whole
//! pipeline. This is a software reference implementation — fine for the
//! simulated-TLS wire-cost benchmarks it backs, not hardened against
//! timing side channels.

use std::sync::OnceLock;

/// Trait surface mirroring the upstream `cipher` crate subset in use.
pub mod cipher {
    /// Construct a cipher from a fixed-size key.
    pub trait KeyInit: Sized {
        fn new(key: &[u8; 16]) -> Self;
    }

    /// Encrypt one 16-byte block in place.
    pub trait BlockEncrypt {
        fn encrypt_block(&self, block: &mut [u8; 16]);
    }
}

/// GF(2⁸) multiplication modulo x⁸ + x⁴ + x³ + x + 1 (0x11B).
fn gf_mul(mut a: u8, mut b: u8) -> u8 {
    let mut acc = 0u8;
    while b != 0 {
        if b & 1 != 0 {
            acc ^= a;
        }
        let hi = a & 0x80;
        a <<= 1;
        if hi != 0 {
            a ^= 0x1B;
        }
        b >>= 1;
    }
    acc
}

fn sbox() -> &'static [u8; 256] {
    static SBOX: OnceLock<[u8; 256]> = OnceLock::new();
    SBOX.get_or_init(|| {
        // Multiplicative inverses by exhaustive search (256² once).
        let mut inv = [0u8; 256];
        for x in 1..=255u8 {
            for y in 1..=255u8 {
                if gf_mul(x, y) == 1 {
                    inv[x as usize] = y;
                    break;
                }
            }
        }
        let mut table = [0u8; 256];
        for (x, slot) in table.iter_mut().enumerate() {
            let b = inv[x];
            let mut s = 0u8;
            for i in 0..8 {
                let bit = (b >> i)
                    ^ (b >> ((i + 4) % 8))
                    ^ (b >> ((i + 5) % 8))
                    ^ (b >> ((i + 6) % 8))
                    ^ (b >> ((i + 7) % 8))
                    ^ (0x63 >> i);
                s |= (bit & 1) << i;
            }
            *slot = s;
        }
        table
    })
}

/// AES-128 with expanded round keys (11 × 16 bytes).
pub struct Aes128 {
    round_keys: [[u8; 16]; 11],
}

impl cipher::KeyInit for Aes128 {
    fn new(key: &[u8; 16]) -> Aes128 {
        let sbox = sbox();
        let mut w = [[0u8; 4]; 44];
        for (i, c) in key.chunks_exact(4).enumerate() {
            w[i].copy_from_slice(c);
        }
        let mut rcon = 1u8;
        for i in 4..44 {
            let mut t = w[i - 1];
            if i % 4 == 0 {
                // RotWord + SubWord + Rcon.
                t = [
                    sbox[t[1] as usize] ^ rcon,
                    sbox[t[2] as usize],
                    sbox[t[3] as usize],
                    sbox[t[0] as usize],
                ];
                rcon = gf_mul(rcon, 2);
            }
            for (out, prev) in t.iter_mut().zip(w[i - 4]) {
                *out ^= prev;
            }
            w[i] = t;
        }
        let mut round_keys = [[0u8; 16]; 11];
        for (r, rk) in round_keys.iter_mut().enumerate() {
            for c in 0..4 {
                rk[4 * c..4 * c + 4].copy_from_slice(&w[4 * r + c]);
            }
        }
        Aes128 { round_keys }
    }
}

impl Aes128 {
    fn add_round_key(state: &mut [u8; 16], rk: &[u8; 16]) {
        for (s, k) in state.iter_mut().zip(rk) {
            *s ^= k;
        }
    }

    fn sub_bytes(state: &mut [u8; 16]) {
        let sbox = sbox();
        for s in state.iter_mut() {
            *s = sbox[*s as usize];
        }
    }

    /// State layout (FIPS 197 §3.4): byte `i` holds `s[i % 4][i / 4]` —
    /// row `r` of the state lives at indices `r, r+4, r+8, r+12`.
    fn shift_rows(state: &mut [u8; 16]) {
        let old = *state;
        for r in 1..4 {
            for c in 0..4 {
                state[4 * c + r] = old[4 * ((c + r) % 4) + r];
            }
        }
    }

    fn mix_columns(state: &mut [u8; 16]) {
        for c in 0..4 {
            let col = [state[4 * c], state[4 * c + 1], state[4 * c + 2], state[4 * c + 3]];
            state[4 * c] = gf_mul(col[0], 2) ^ gf_mul(col[1], 3) ^ col[2] ^ col[3];
            state[4 * c + 1] = col[0] ^ gf_mul(col[1], 2) ^ gf_mul(col[2], 3) ^ col[3];
            state[4 * c + 2] = col[0] ^ col[1] ^ gf_mul(col[2], 2) ^ gf_mul(col[3], 3);
            state[4 * c + 3] = gf_mul(col[0], 3) ^ col[1] ^ col[2] ^ gf_mul(col[3], 2);
        }
    }
}

impl cipher::BlockEncrypt for Aes128 {
    fn encrypt_block(&self, block: &mut [u8; 16]) {
        Self::add_round_key(block, &self.round_keys[0]);
        for round in 1..10 {
            Self::sub_bytes(block);
            Self::shift_rows(block);
            Self::mix_columns(block);
            Self::add_round_key(block, &self.round_keys[round]);
        }
        Self::sub_bytes(block);
        Self::shift_rows(block);
        Self::add_round_key(block, &self.round_keys[10]);
    }
}

#[cfg(test)]
mod tests {
    use super::cipher::{BlockEncrypt, KeyInit};
    use super::*;

    #[test]
    fn sbox_known_entries() {
        let s = sbox();
        // S-box corners from FIPS 197 Fig. 7.
        assert_eq!(s[0x00], 0x63);
        assert_eq!(s[0x01], 0x7c);
        assert_eq!(s[0x53], 0xed);
        assert_eq!(s[0xff], 0x16);
    }

    #[test]
    fn fips197_appendix_c1_vector() {
        let key: [u8; 16] = [
            0x00, 0x01, 0x02, 0x03, 0x04, 0x05, 0x06, 0x07, 0x08, 0x09, 0x0a, 0x0b, 0x0c,
            0x0d, 0x0e, 0x0f,
        ];
        let mut block: [u8; 16] = [
            0x00, 0x11, 0x22, 0x33, 0x44, 0x55, 0x66, 0x77, 0x88, 0x99, 0xaa, 0xbb, 0xcc,
            0xdd, 0xee, 0xff,
        ];
        let expect: [u8; 16] = [
            0x69, 0xc4, 0xe0, 0xd8, 0x6a, 0x7b, 0x04, 0x30, 0xd8, 0xcd, 0xb7, 0x80, 0x70,
            0xb4, 0xc5, 0x5a,
        ];
        let aes = Aes128::new(&key);
        aes.encrypt_block(&mut block);
        assert_eq!(block, expect);
    }

    #[test]
    fn gf_mul_basics() {
        assert_eq!(gf_mul(0x57, 0x13), 0xfe); // FIPS 197 §4.2 example
        assert_eq!(gf_mul(1, 0xAB), 0xAB);
        assert_eq!(gf_mul(0, 0xFF), 0);
    }

    #[test]
    fn distinct_blocks_encrypt_distinct() {
        let aes = Aes128::new(&[7u8; 16]);
        let mut a = [0u8; 16];
        let mut b = [0u8; 16];
        b[0] = 1;
        aes.encrypt_block(&mut a);
        aes.encrypt_block(&mut b);
        assert_ne!(a, b);
    }
}
