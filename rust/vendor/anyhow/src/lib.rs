//! Minimal, API-compatible subset of the `anyhow` crate, vendored so the
//! workspace builds fully offline.
//!
//! Provides the surface this workspace actually uses:
//!
//! * [`Error`] — a context-chain error type (no backtraces),
//! * [`Result<T>`] — alias defaulting the error type,
//! * [`anyhow!`], [`bail!`], [`ensure!`] — construction macros,
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`.
//!
//! Formatting matches the upstream conventions the code relies on:
//! `{e}` prints the outermost message, `{e:#}` prints the full chain as
//! `outer: inner: ...`, and `{e:?}` prints the chain in a `Caused by`
//! block. Like upstream, [`Error`] deliberately does **not** implement
//! `std::error::Error`, which is what makes the blanket `From` impl for
//! every `std::error::Error` type possible.

use std::fmt;

/// Context-chain error: the head message plus an optional wrapped cause.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Build an error from any displayable message.
    pub fn msg(m: impl fmt::Display) -> Error {
        Error { msg: m.to_string(), source: None }
    }

    /// Wrap this error with an outer context message.
    pub fn context(self, context: impl fmt::Display) -> Error {
        Error { msg: context.to_string(), source: Some(Box::new(self)) }
    }

    /// The cause chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &Error> {
        let mut next = Some(self);
        std::iter::from_fn(move || {
            let cur = next?;
            next = cur.source.as_deref();
            Some(cur)
        })
    }

    /// The innermost error in the chain.
    pub fn root_cause(&self) -> &Error {
        self.chain().last().expect("chain is never empty")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` — the full chain, colon-separated (upstream format).
            let mut first = true;
            for e in self.chain() {
                if !first {
                    write!(f, ": ")?;
                }
                write!(f, "{}", e.msg)?;
                first = false;
            }
            Ok(())
        } else {
            write!(f, "{}", self.msg)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if self.source.is_some() {
            write!(f, "\n\nCaused by:")?;
            for e in self.chain().skip(1) {
                write!(f, "\n    {}", e.msg)?;
            }
        }
        Ok(())
    }
}

// Any std error converts into `Error` (so `?` works across error types).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        // Preserve the std source chain as context entries.
        let mut chain: Vec<String> = Vec::new();
        chain.push(e.to_string());
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        let mut err: Option<Error> = None;
        for msg in chain.into_iter().rev() {
            err = Some(match err {
                None => Error { msg, source: None },
                Some(inner) => Error { msg, source: Some(Box::new(inner)) },
            });
        }
        err.expect("non-empty chain")
    }
}

/// Attach context to fallible values (`Result` / `Option`).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: `{}`", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "file missing")
    }

    #[test]
    fn display_plain_and_alternate() {
        let e = Error::msg("inner").context("middle").context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: middle: inner");
        assert!(format!("{e:?}").contains("Caused by:"));
        assert_eq!(e.root_cause().to_string(), "inner");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("opening config").unwrap_err();
        assert_eq!(format!("{e:#}"), "opening config: file missing");

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", "key")).unwrap_err();
        assert_eq!(e.to_string(), "missing key");
    }

    #[test]
    fn macros_build_errors() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative input {x}");
            if x == 0 {
                bail!("zero not allowed");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(f(0).unwrap_err().to_string(), "zero not allowed");
        assert_eq!(f(-2).unwrap_err().to_string(), "negative input -2");
        let owned: String = "owned message".into();
        assert_eq!(anyhow!(owned).to_string(), "owned message");
        assert_eq!(anyhow!("x = {}", 7).to_string(), "x = 7");
    }

    #[test]
    fn std_error_chain_is_preserved() {
        let e: Error = io_err().into();
        assert_eq!(e.to_string(), "file missing");
    }
}
