//! Minimal HMAC (RFC 2104) over the vendored SHA-256, exposing the
//! `hmac` crate's call surface (`Hmac<Sha256>` + the `Mac` trait with
//! `new_from_slice` / `update` / `finalize().into_bytes()`), so the
//! workspace builds fully offline.

use sha2::{Digest, Sha256};
use std::marker::PhantomData;

const BLOCK: usize = 64;

/// HMAC keyed by digest `D` (only `Sha256` is instantiated here).
pub struct Hmac<D> {
    inner: Sha256,
    opad_key: [u8; BLOCK],
    _d: PhantomData<D>,
}

/// Error for invalid key lengths — HMAC accepts any length, so this is
/// uninhabited in practice; kept for API parity.
#[derive(Debug)]
pub struct InvalidLength;

impl std::fmt::Display for InvalidLength {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "invalid HMAC key length")
    }
}

impl std::error::Error for InvalidLength {}

/// Finalized MAC output (stands in for the upstream `CtOutput`).
pub struct CtOutput([u8; 32]);

impl CtOutput {
    pub fn into_bytes(self) -> [u8; 32] {
        self.0
    }
}

/// Subset of the `digest::Mac` trait used by this workspace.
pub trait Mac: Sized {
    fn new_from_slice(key: &[u8]) -> Result<Self, InvalidLength>;
    fn update(&mut self, data: &[u8]);
    fn finalize(self) -> CtOutput;
}

impl Mac for Hmac<Sha256> {
    fn new_from_slice(key: &[u8]) -> Result<Self, InvalidLength> {
        // Keys longer than the block size are hashed first (RFC 2104).
        let mut k = [0u8; BLOCK];
        if key.len() > BLOCK {
            let mut h = Sha256::new();
            h.update(key);
            k[..32].copy_from_slice(&h.finalize());
        } else {
            k[..key.len()].copy_from_slice(key);
        }
        let mut ipad_key = [0u8; BLOCK];
        let mut opad_key = [0u8; BLOCK];
        for i in 0..BLOCK {
            ipad_key[i] = k[i] ^ 0x36;
            opad_key[i] = k[i] ^ 0x5c;
        }
        let mut inner = Sha256::new();
        inner.update(ipad_key);
        Ok(Hmac { inner, opad_key, _d: PhantomData })
    }

    fn update(&mut self, data: &[u8]) {
        self.inner.update(data);
    }

    fn finalize(self) -> CtOutput {
        let inner_hash = self.inner.finalize();
        let mut outer = Sha256::new();
        outer.update(self.opad_key);
        outer.update(inner_hash);
        CtOutput(outer.finalize())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hex(bytes: &[u8]) -> String {
        bytes.iter().map(|b| format!("{b:02x}")).collect()
    }

    fn hmac(key: &[u8], msg: &[u8]) -> String {
        let mut m = <Hmac<Sha256> as Mac>::new_from_slice(key).unwrap();
        m.update(msg);
        hex(&m.finalize().into_bytes())
    }

    #[test]
    fn rfc4231_test_case_1() {
        // Key = 20 x 0x0b, data = "Hi There".
        assert_eq!(
            hmac(&[0x0b; 20], b"Hi There"),
            "b0344c61d8db38535ca8afceaf0bf12b881dc200c9833da726e9376c2e32cff7"
        );
    }

    #[test]
    fn rfc4231_test_case_2() {
        assert_eq!(
            hmac(b"Jefe", b"what do ya want for nothing?"),
            "5bdcc146bf60754e6a042426089575c75a003f089d2739839dec58b964ec3843"
        );
    }

    #[test]
    fn long_keys_are_hashed_first() {
        // A >64-byte key must hash to the same MAC as its SHA-256 digest
        // used as the key directly.
        let long_key = vec![0xAAu8; 100];
        let mut h = Sha256::new();
        h.update(&long_key);
        let short = h.finalize();
        assert_eq!(hmac(&long_key, b"msg"), hmac(&short, b"msg"));
    }

    #[test]
    fn incremental_update_matches_one_shot() {
        let mut a = <Hmac<Sha256> as Mac>::new_from_slice(b"key").unwrap();
        a.update(b"hello ");
        a.update(b"world");
        let mut b = <Hmac<Sha256> as Mac>::new_from_slice(b"key").unwrap();
        b.update(b"hello world");
        assert_eq!(a.finalize().into_bytes(), b.finalize().into_bytes());
    }
}
