//! Minimal, API-compatible subset of the `once_cell` crate, vendored so
//! the workspace builds fully offline. Only `sync::Lazy` is provided —
//! the single type this workspace uses — implemented over
//! `std::sync::OnceLock`.

pub mod sync {
    use std::ops::Deref;
    use std::sync::OnceLock;

    /// A value lazily initialized on first access, safe to use in
    /// `static` items (`new` is `const`).
    pub struct Lazy<T, F = fn() -> T> {
        cell: OnceLock<T>,
        init: F,
    }

    impl<T, F: Fn() -> T> Lazy<T, F> {
        pub const fn new(init: F) -> Lazy<T, F> {
            Lazy { cell: OnceLock::new(), init }
        }

        /// Force initialization and return the value.
        pub fn force(this: &Lazy<T, F>) -> &T {
            this.cell.get_or_init(|| (this.init)())
        }
    }

    impl<T, F: Fn() -> T> Deref for Lazy<T, F> {
        type Target = T;
        fn deref(&self) -> &T {
            Lazy::force(self)
        }
    }

    #[cfg(test)]
    mod tests {
        use super::*;
        use std::sync::atomic::{AtomicUsize, Ordering};

        static CALLS: AtomicUsize = AtomicUsize::new(0);
        static VALUE: Lazy<u64> = Lazy::new(|| {
            CALLS.fetch_add(1, Ordering::SeqCst);
            42
        });

        #[test]
        fn initializes_once_and_derefs() {
            assert_eq!(*VALUE, 42);
            assert_eq!(*VALUE, 42);
            assert_eq!(CALLS.load(Ordering::SeqCst), 1);
        }
    }
}
