//! Stub of the `xla` (PJRT bindings) crate, vendored so the workspace
//! builds fully offline on machines without the real XLA toolchain.
//!
//! The API surface mirrors exactly what `metisfl::runtime` calls. Every
//! entry point that would need a real PJRT runtime returns an [`Error`],
//! starting with [`PjRtClient::cpu`] — so the runtime's service thread
//! takes its existing "client unavailable" degradation path, the XLA
//! aggregation backend falls back to the CPU engine, and the
//! artifact-gated tests self-skip. Swap this path dependency for the real
//! `xla` crate to enable PJRT execution; no `metisfl` source changes are
//! required.

use std::fmt;
use std::path::Path;

/// Stub error: names the operation that required a real PJRT runtime.
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{} (vendored xla stub: real PJRT bindings not linked)", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(op: &str) -> Result<T> {
    Err(Error(format!("{op} unavailable")))
}

/// PJRT client handle. The stub can never be constructed: [`cpu`]
/// always fails, so the methods below are unreachable in practice.
///
/// [`cpu`]: PjRtClient::cpu
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PJRT CPU client")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("compile")
    }
}

/// Parsed HLO module.
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<HloModuleProto> {
        unavailable("HLO text parsing")
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// A compiled executable (never constructible through the stub).
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("execute")
    }
}

/// A device buffer (never constructible through the stub).
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("buffer fetch")
    }
}

/// A host literal.
pub struct Literal(());

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal(())
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal(()))
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        unavailable("untuple")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable("literal read")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_construction_fails_with_clear_error() {
        let e = PjRtClient::cpu().err().unwrap();
        let msg = e.to_string();
        assert!(msg.contains("stub"), "{msg}");
        assert!(msg.contains("PJRT"), "{msg}");
    }

    #[test]
    fn literal_shape_plumbing_is_inert() {
        let lit = Literal::vec1(&[1.0, 2.0]);
        assert!(lit.reshape(&[2, 1]).is_ok());
        assert!(lit.to_vec::<f32>().is_err());
        assert!(lit.to_tuple().is_err());
    }
}
