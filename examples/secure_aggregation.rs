//! Secure aggregation demo (Table 1, Privacy & Security): one federated
//! round where the controller never sees an individual update in the
//! clear, under both schemes the crypto module ships:
//!
//! * pairwise-PRG masking (Flower/FedML LightSecAgg analog) — masks
//!   cancel in the sum;
//! * mock-CKKS additively homomorphic aggregation (PALISADE analog) —
//!   the controller sums ciphertexts and only the key holder decrypts.
//!
//! Both results are checked against the plaintext FedAvg engine.
//!
//!     cargo run --release --example secure_aggregation

use metisfl::config::ModelSpec;
use metisfl::controller::aggregation::{Backend, WeightedSum};
use metisfl::crypto::{CkksContext, PairwiseMasker};
use metisfl::tensor::TensorModel;
use metisfl::util::{Rng, Stopwatch};

fn main() -> anyhow::Result<()> {
    let spec = ModelSpec::mlp(8, 6, 32);
    let n = 8;
    println!("{} learners, model {} params\n", n, spec.param_count());

    // Learner updates (equal sample counts → uniform FedAvg weights).
    let layout = spec.tensor_layout();
    let mut rng = Rng::new(99);
    let updates: Vec<std::sync::Arc<TensorModel>> = (0..n)
        .map(|_| std::sync::Arc::new(TensorModel::random_init(&layout, &mut rng)))
        .collect();
    let coeffs = vec![1.0 / n as f64; n];
    let plain = WeightedSum::compute(&updates, &coeffs, &Backend::Sequential)?;

    // --- pairwise masking ----------------------------------------------
    let group_secret = [42u8; 32];
    let sw = Stopwatch::start();
    // Each learner pre-scales by its FedAvg weight and masks.
    let masked: Vec<Vec<i64>> = updates
        .iter()
        .enumerate()
        .map(|(i, m)| {
            let scaled: Vec<f32> =
                m.to_flat().iter().map(|v| v * coeffs[i] as f32).collect();
            PairwiseMasker::new(i, n, 1, group_secret).mask(&scaled)
        })
        .collect();
    // The controller sums masked vectors; masks cancel.
    let summed = PairwiseMasker::unmask_sum(&masked);
    let masked_model = TensorModel::from_flat(&layout, &summed)?;
    let mask_time = sw.elapsed();
    let mask_err = plain.max_abs_diff(&masked_model);
    println!("masking secure-agg:  {mask_time:>10?}   max |err| vs plaintext {mask_err:.2e}");
    assert!(mask_err < 1e-3);

    // A single masked update must look random (controller learns nothing).
    let zeros = vec![0.0f32; spec.param_count()];
    let masked_zero = PairwiseMasker::new(0, n, 1, group_secret).mask(&zeros);
    let nonzero = masked_zero.iter().filter(|&&v| v != 0).count();
    println!(
        "  individual update hidden: {}/{} mask words non-zero for an all-zero update",
        nonzero,
        masked_zero.len()
    );

    // --- mock-CKKS -------------------------------------------------------
    let ctx = CkksContext::new([7u8; 32]);
    let mut enc_rng = Rng::new(123);
    let sw = Stopwatch::start();
    let cts: Vec<_> = updates
        .iter()
        .enumerate()
        .map(|(i, m)| {
            let scaled: Vec<f32> =
                m.to_flat().iter().map(|v| v * coeffs[i] as f32).collect();
            ctx.encrypt(&scaled, i as u64, &mut enc_rng)
        })
        .collect();
    let sum_ct = ctx.sum(&cts)?;
    let decrypted = ctx.decrypt(&sum_ct);
    let ckks_model = TensorModel::from_flat(&layout, &decrypted)?;
    let ckks_time = sw.elapsed();
    let ckks_err = plain.max_abs_diff(&ckks_model);
    let expansion = sum_ct.byte_size() as f64 / (spec.param_count() * 4) as f64;
    println!("mock-CKKS secure-agg:{ckks_time:>10?}   max |err| vs plaintext {ckks_err:.2e}");
    println!("  ciphertext expansion {expansion:.2}x payload");
    assert!(ckks_err < 1e-2);

    println!("\nOK: both secure paths reproduce plaintext FedAvg within tolerance.");
    Ok(())
}
