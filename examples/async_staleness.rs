//! Asynchronous protocol demo (the paper's Table-1 differentiator):
//! heterogeneous learners (1x..8x speed spread) under (a) synchronous and
//! (b) asynchronous execution, comparing wall-clock per community update
//! and showing staleness-discounted mixing at work.
//!
//!     cargo run --release --example async_staleness

use metisfl::config::{FederationEnv, ModelSpec, Protocol};
use metisfl::driver;
use metisfl::learner::{SyntheticTrainer, Trainer};
use std::sync::Arc;

fn run(protocol: Protocol, label: &str) -> anyhow::Result<std::time::Duration> {
    let learners = 6;
    let env = FederationEnv::builder(&format!("async-demo-{label}"))
        .learners(learners)
        .rounds(4)
        .model(ModelSpec::mlp(8, 6, 16))
        .samples_per_learner(50)
        .batch_size(10)
        .protocol(protocol)
        .heartbeat_ms(10_000)
        .build();
    // Learner i is (i+1)x slower than learner 0: a realistic straggler mix.
    let report = driver::run_with_trainer(&env, |idx| {
        Arc::new(SyntheticTrainer::new(500 * (idx as u64 + 1), 0.01)) as Arc<dyn Trainer>
    })?;
    let per_update = report.wall_clock / (env.rounds * learners).max(1) as u32;
    println!(
        "{label:<14} wall {:>10?}   per community-update {:>10?}",
        report.wall_clock, per_update
    );
    Ok(report.wall_clock)
}

fn main() -> anyhow::Result<()> {
    println!("6 learners, speeds 1x..6x slower, 4 rounds\n");
    let sync = run(Protocol::Synchronous, "synchronous")?;
    let semi = run(Protocol::SemiSynchronous { lambda: 1.0 }, "semi-sync")?;
    let asyn = run(Protocol::Asynchronous { staleness_alpha: 0.5 }, "asynchronous")?;
    println!(
        "\nasync vs sync wall-clock: {:.2}x   semi-sync vs sync: {:.2}x",
        sync.as_secs_f64() / asyn.as_secs_f64(),
        sync.as_secs_f64() / semi.as_secs_f64()
    );
    println!("(sync waits for the slowest learner every round; async updates the");
    println!(" community model on every completion, discounted by staleness^-α)");
    Ok(())
}
