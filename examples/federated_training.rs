//! End-to-end validation driver (DESIGN.md E9): real federated training
//! through all three layers — Rust controller/learners (L3) executing the
//! AOT-compiled JAX model (L2) whose forward/update paths are Pallas
//! kernels (L1), via PJRT. Logs the community loss curve per round.
//!
//!     make artifacts                 # exports the tiny+small variants
//!     cargo run --release --example federated_training
//!
//! Options: --learners N --rounds R --variant tiny|small --distributed
//! The run is recorded in EXPERIMENTS.md §E9.

use metisfl::cli::Command;
use metisfl::config::{FederationEnv, ModelSpec, TrainerKind};
use metisfl::runtime::Artifacts;

fn main() -> anyhow::Result<()> {
    let cmd = Command::new("federated_training", "end-to-end XLA federated training")
        .opt("learners", Some("10"), "number of learners")
        .opt("rounds", Some("20"), "federation rounds")
        .opt("variant", Some("small"), "artifact variant: tiny | small")
        .opt("artifacts", Some("artifacts"), "artifacts directory")
        .flag("distributed", "use localhost TCP instead of in-proc");
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let a = match cmd.parse(&raw) {
        Ok(a) => a,
        Err(metisfl::cli::CliError::Help) => {
            println!("{}", cmd.help());
            return Ok(());
        }
        Err(e) => return Err(e.into()),
    };

    let dir = a.get("artifacts").unwrap();
    let (spec, samples, batch) = match a.get("variant").unwrap() {
        "tiny" => (ModelSpec::mlp(4, 2, 8), 64, 16),
        "small" => (ModelSpec::mlp(8, 4, 32), 200, 100),
        other => anyhow::bail!("unknown variant '{other}'"),
    };

    // Fail early with a helpful message if artifacts are missing.
    let arts = Artifacts::load(dir)?;
    arts.for_spec(&spec)?;

    let env = FederationEnv::builder("federated-training")
        .learners(a.get_usize("learners")?)
        .rounds(a.get_usize("rounds")?)
        .model(spec.clone())
        .samples_per_learner(samples)
        .batch_size(batch)
        .learning_rate(0.02)
        .trainer(TrainerKind::Xla { artifacts_dir: dir.to_string() })
        .build();

    println!(
        "federated training: {} learners x {} rounds, model {} ({} params), real XLA local SGD",
        env.learners,
        env.rounds,
        spec.variant_name(),
        spec.param_count()
    );

    let report = if a.flag("distributed") {
        metisfl::driver::run_distributed(&env)?
    } else {
        metisfl::driver::run_simulated(&env)?
    };

    println!("\nloss curve (community MSE on held-out local test sets):");
    println!("{:<7} {:>12} {:>16} {:>16}", "round", "eval_loss", "aggregation", "fed_round");
    let mut first = None;
    let mut last = None;
    for r in &report.round_metrics {
        let loss = r.community_eval_loss.unwrap_or(f64::NAN);
        if first.is_none() {
            first = Some(loss);
        }
        last = Some(loss);
        println!(
            "{:<7} {:>12.5} {:>16} {:>16}",
            r.round,
            loss,
            format!("{:?}", r.aggregation),
            format!("{:?}", r.federation_round)
        );
    }
    let (first, last) = (first.unwrap_or(f64::NAN), last.unwrap_or(f64::NAN));
    println!(
        "\nwall clock {:?}; loss {first:.5} -> {last:.5} ({:.1}% reduction)",
        report.wall_clock,
        100.0 * (1.0 - last / first)
    );
    anyhow::ensure!(last < first, "training did not reduce the community loss");
    println!("OK: all three layers compose; training converges.");
    Ok(())
}
