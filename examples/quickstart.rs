//! Quickstart: a 5-learner simulated federation in ~20 lines.
//!
//!     cargo run --release --example quickstart
//!
//! Uses the synthetic stress trainer (no artifacts needed). For real
//! XLA-backed local training see `federated_training.rs`.

use metisfl::prelude::*;

fn main() -> anyhow::Result<()> {
    let env = FederationEnv::builder("quickstart")
        .learners(5)
        .rounds(3)
        .model(ModelSpec::mlp(8, 10, 32)) // 10 hidden layers x 32 units
        .samples_per_learner(100)
        .batch_size(100)
        .build();

    let report = run_simulated(&env)?;

    println!("federation '{}' completed in {:?}", report.env_name, report.wall_clock);
    for r in &report.round_metrics {
        println!(
            "round {}: {}/{} learners, dispatch {:?}, aggregation {:?}, total {:?}",
            r.round, r.completed, r.participants, r.train_dispatch, r.aggregation,
            r.federation_round
        );
    }
    println!("final community eval loss: {:?}", report.final_loss);
    Ok(())
}
