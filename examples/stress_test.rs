//! Mini version of the paper's §4.2 stress test: one (model size,
//! learner count) cell across all six framework profiles, printing the
//! per-operation breakdown of Figs. 5–7. For the full sweeps use
//! `cargo bench --bench fig5|fig6|fig7` (FULL=1 for the paper's grid).
//!
//!     cargo run --release --example stress_test -- --learners 25 --layers 20 --units 32

use metisfl::baselines::Framework;
use metisfl::cli::Command;
use metisfl::config::ModelSpec;
use metisfl::harness::{figure_sweep, FigureConfig};

fn main() -> anyhow::Result<()> {
    let cmd = Command::new("stress_test", "one cross-framework stress cell")
        .opt("learners", Some("25"), "number of learners")
        .opt("layers", Some("20"), "hidden layers")
        .opt("units", Some("32"), "units per hidden layer");
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let a = match cmd.parse(&raw) {
        Ok(a) => a,
        Err(metisfl::cli::CliError::Help) => {
            println!("{}", cmd.help());
            return Ok(());
        }
        Err(e) => return Err(e.into()),
    };
    let config = FigureConfig {
        name: "stress_example",
        spec: ModelSpec::mlp(8, a.get_usize("layers")?, a.get_usize("units")?),
        learner_counts: vec![a.get_usize("learners")?],
        frameworks: Framework::ALL.to_vec(),
        seed: 42,
    };
    let result = figure_sweep(config);
    result.emit_panels()?;
    println!("\n(aggregation column for MetisFL gRPC+OMP is modelled at 32 cores on");
    println!(" 1-core machines — see DESIGN.md §Substitutions; CSVs in bench_out/)");
    Ok(())
}
