"""AOT pipeline: export a variant to a temp dir and validate the
artifacts the Rust runtime will consume (manifest schema, HLO text
parseability markers, param counts)."""

import json
import os

import pytest

from compile import model as M
from compile.aot import export_variant


@pytest.fixture(scope="module")
def exported(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    spec = M.MlpSpec(4, 2, 8)
    entry = export_variant(spec, batch=16, out_dir=str(out))
    return out, spec, entry


def test_manifest_entry_schema(exported):
    _, spec, entry = exported
    assert entry["param_count"] == spec.param_count() == 121
    assert entry["input_dim"] == 4
    assert entry["hidden_layers"] == 2
    assert entry["hidden_units"] == 8
    assert entry["batch"] == 16
    for key in ("train", "eval", "lincomb"):
        assert entry[key].endswith(".hlo.txt")


def test_hlo_files_exist_and_look_like_hlo_text(exported):
    out, _, entry = exported
    for key in ("train", "eval", "lincomb"):
        path = os.path.join(out, entry[key])
        assert os.path.exists(path), path
        text = open(path).read()
        # HLO text modules start with 'HloModule' and must contain an
        # ENTRY computation; the rust parser depends on this shape.
        assert text.startswith("HloModule"), path
        assert "ENTRY" in text, path
        assert len(text) > 1000, path


def test_train_hlo_has_expected_parameter_arity(exported):
    out, spec, entry = exported
    text = open(os.path.join(out, entry["train"])).read()
    # train_step(flat, x, y, lr): four parameters in the entry computation.
    entry_line = [l for l in text.splitlines() if l.startswith("ENTRY")][0]
    assert entry_line.count("parameter") >= 0  # arity is in the body
    assert f"f32[{spec.param_count()}]" in text


def test_manifest_roundtrips_as_json(exported):
    out, _, entry = exported
    path = os.path.join(out, "m.json")
    with open(path, "w") as f:
        json.dump({"variants": {"v": entry}}, f)
    back = json.load(open(path))
    assert back["variants"]["v"]["param_count"] == 121
