"""L1 correctness: every Pallas kernel vs the pure-jnp oracle.

Hypothesis sweeps shapes/dtypes; fixed cases pin the paper's widths
(32/100/320) and edge blocks.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import fused_dense, lincomb, sgd_update, weighted_aggregate
from compile.kernels.ref import (
    fused_dense_ref,
    lincomb_ref,
    sgd_update_ref,
    weighted_aggregate_ref,
)

jax.config.update("jax_platform_name", "cpu")


def rand(key, *shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, dtype=jnp.float32)


# ----------------------------------------------------------------- dense


@pytest.mark.parametrize("units", [32, 100, 320])  # the paper's widths
@pytest.mark.parametrize("relu", [True, False])
def test_fused_dense_paper_widths(units, relu):
    x = rand(0, 100, 8)
    w = rand(1, 8, units)
    b = rand(2, units)
    got = fused_dense(x, w, b, relu=relu)
    want = fused_dense_ref(x, w, b, relu=relu)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("batch,in_dim,out_dim", [(1, 1, 1), (7, 3, 5), (128, 128, 128)])
def test_fused_dense_edge_shapes(batch, in_dim, out_dim):
    x = rand(3, batch, in_dim)
    w = rand(4, in_dim, out_dim)
    b = rand(5, out_dim)
    np.testing.assert_allclose(
        fused_dense(x, w, b), fused_dense_ref(x, w, b), rtol=1e-5, atol=1e-5
    )


@settings(max_examples=25, deadline=None)
@given(
    batch=st.integers(1, 64),
    in_dim=st.integers(1, 48),
    out_dim=st.integers(1, 48),
    relu=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_fused_dense_hypothesis(batch, in_dim, out_dim, relu, seed):
    k = jax.random.PRNGKey(seed)
    kx, kw, kb = jax.random.split(k, 3)
    x = jax.random.normal(kx, (batch, in_dim), dtype=jnp.float32)
    w = jax.random.normal(kw, (in_dim, out_dim), dtype=jnp.float32)
    b = jax.random.normal(kb, (out_dim,), dtype=jnp.float32)
    got = fused_dense(x, w, b, relu=relu)
    want = fused_dense_ref(x, w, b, relu=relu)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_fused_dense_block_clamping():
    # Width 100 does not divide 128: _block must fall back to a divisor.
    x = rand(6, 60, 100)
    w = rand(7, 100, 100)
    b = rand(8, 100)
    np.testing.assert_allclose(
        fused_dense(x, w, b), fused_dense_ref(x, w, b), rtol=1e-5, atol=1e-5
    )


# --------------------------------------------------------------- lincomb


@pytest.mark.parametrize("d", [1, 7, 1024, 100_000])
def test_lincomb_sizes(d):
    a = rand(9, d)
    b = rand(10, d)
    got = lincomb(a, b, jnp.float32(0.25), jnp.float32(0.75))
    want = lincomb_ref(a, b, 0.25, 0.75)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(
    d=st.integers(1, 4096),
    wa=st.floats(-2, 2, allow_nan=False, width=32),
    wb=st.floats(-2, 2, allow_nan=False, width=32),
    seed=st.integers(0, 2**31 - 1),
)
def test_lincomb_hypothesis(d, wa, wb, seed):
    k = jax.random.PRNGKey(seed)
    ka, kb = jax.random.split(k)
    a = jax.random.normal(ka, (d,), dtype=jnp.float32)
    b = jax.random.normal(kb, (d,), dtype=jnp.float32)
    got = lincomb(a, b, jnp.float32(wa), jnp.float32(wb))
    np.testing.assert_allclose(got, lincomb_ref(a, b, wa, wb), rtol=1e-4, atol=1e-4)


def test_lincomb_fold_equals_weighted_sum():
    # The Rust backend folds lincomb over N models; verify the fold.
    n, d = 5, 333
    models = [rand(20 + i, d) for i in range(n)]
    coeffs = np.array([0.1, 0.3, 0.2, 0.25, 0.15], dtype=np.float32)
    acc = models[0]
    acc_w = coeffs[0]
    for m, c in zip(models[1:], coeffs[1:]):
        acc = lincomb(acc, m, jnp.float32(acc_w), jnp.float32(c))
        acc_w = 1.0
    want = sum(c * m for c, m in zip(coeffs, models))
    np.testing.assert_allclose(acc, want, rtol=1e-4, atol=1e-5)


# ----------------------------------------------------- weighted_aggregate


@pytest.mark.parametrize("n", [1, 2, 10, 50])
def test_weighted_aggregate_learner_counts(n):
    stack = rand(11, n, 257)
    w = jnp.abs(rand(12, n)) + 0.01
    w = w / w.sum()
    got = weighted_aggregate(stack, w)
    np.testing.assert_allclose(
        got, weighted_aggregate_ref(stack, w), rtol=1e-4, atol=1e-5
    )


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 16), d=st.integers(1, 2048), seed=st.integers(0, 2**31 - 1))
def test_weighted_aggregate_hypothesis(n, d, seed):
    k = jax.random.PRNGKey(seed)
    ks, kw = jax.random.split(k)
    stack = jax.random.normal(ks, (n, d), dtype=jnp.float32)
    w = jax.random.uniform(kw, (n,), dtype=jnp.float32)
    got = weighted_aggregate(stack, w)
    np.testing.assert_allclose(
        got, weighted_aggregate_ref(stack, w), rtol=1e-3, atol=1e-4
    )


# ---------------------------------------------------------------- sgd


@pytest.mark.parametrize("d", [1, 129, 65536])
def test_sgd_update_sizes(d):
    p = rand(13, d)
    g = rand(14, d)
    got = sgd_update(p, g, jnp.float32(0.05))
    np.testing.assert_allclose(got, sgd_update_ref(p, g, 0.05), rtol=1e-6, atol=1e-6)


def test_sgd_update_zero_lr_is_identity():
    p = rand(15, 100)
    g = rand(16, 100)
    np.testing.assert_array_equal(sgd_update(p, g, jnp.float32(0.0)), p)
