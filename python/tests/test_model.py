"""L2 correctness: model shapes, Pallas vs pure-jnp forward parity,
train-step learning behaviour, and Rust-layout interface contract."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

jax.config.update("jax_platform_name", "cpu")

TINY = M.MlpSpec(4, 2, 8)
SMALL = M.MlpSpec(8, 4, 32)


def test_paper_variant_param_counts():
    # §4.2 footnote 4.
    assert 90_000 < M.PAPER_100K.param_count() < 130_000
    assert 900_000 < M.PAPER_1M.param_count() < 1_100_000
    assert 9_500_000 < M.PAPER_10M.param_count() < 10_600_000


def test_layout_matches_rust_model_spec():
    # Mirror of ModelSpec::tensor_layout() — names and order must agree.
    layout = TINY.layout()
    assert layout[0] == ((4, 8), "dense_0/w")
    assert layout[1] == ((8,), "dense_0/b")
    assert layout[-2] == ((8, 1), "head/w")
    assert layout[-1] == ((1,), "head/b")
    assert TINY.variant_name() == "mlp_l2_u8_in4_out1"
    assert TINY.param_count() == 121


def test_flatten_unflatten_roundtrip():
    key = jax.random.PRNGKey(0)
    flat = M.init_params(SMALL, key)
    assert flat.shape == (SMALL.param_count(),)
    tensors = M.unflatten(SMALL, flat)
    assert len(tensors) == 2 * SMALL.hidden_layers + 2
    back = M.flatten(tensors)
    np.testing.assert_array_equal(flat, back)


def test_init_biases_zero():
    flat = M.init_params(TINY, jax.random.PRNGKey(1))
    tensors = M.unflatten(TINY, flat)
    for t, (shape, name) in zip(tensors, TINY.layout()):
        if len(shape) == 1:
            assert np.all(np.asarray(t) == 0.0), name


@pytest.mark.parametrize("spec", [TINY, SMALL])
def test_pallas_forward_matches_pure_jnp(spec):
    key = jax.random.PRNGKey(2)
    flat = M.init_params(spec, key)
    x = jax.random.normal(jax.random.PRNGKey(3), (16, spec.input_dim), dtype=jnp.float32)
    with_pallas = M.forward(spec, flat, x, use_pallas=True)
    without = M.forward(spec, flat, x, use_pallas=False)
    np.testing.assert_allclose(with_pallas, without, rtol=1e-5, atol=1e-5)


def test_train_step_reduces_loss():
    spec = TINY
    key = jax.random.PRNGKey(4)
    flat = M.init_params(spec, key)
    x = jax.random.normal(jax.random.PRNGKey(5), (32, spec.input_dim), dtype=jnp.float32)
    y = jnp.sum(x, axis=1)
    step = jax.jit(M.make_train_step(spec))
    losses = []
    for _ in range(40):
        flat, loss = step(flat, x, y, jnp.float32(0.02))
        losses.append(float(loss))
    assert losses[-1] < 0.5 * losses[0], losses[:3] + losses[-3:]


def test_train_step_pallas_matches_pure_jnp_numerics():
    spec = TINY
    flat0 = M.init_params(spec, jax.random.PRNGKey(6))
    x = jax.random.normal(jax.random.PRNGKey(7), (8, spec.input_dim), dtype=jnp.float32)
    y = jnp.sum(x, axis=1)
    sp = jax.jit(M.make_train_step(spec, use_pallas=True))
    sj = jax.jit(M.make_train_step(spec, use_pallas=False))
    fp, lp = sp(flat0, x, y, jnp.float32(0.01))
    fj, lj = sj(flat0, x, y, jnp.float32(0.01))
    np.testing.assert_allclose(float(lp), float(lj), rtol=1e-5)
    np.testing.assert_allclose(fp, fj, rtol=1e-4, atol=1e-5)


def test_eval_step_returns_finite_scalar_tuple():
    spec = TINY
    flat = M.init_params(spec, jax.random.PRNGKey(8))
    x = jax.random.normal(jax.random.PRNGKey(9), (16, spec.input_dim), dtype=jnp.float32)
    y = jnp.zeros((16,), dtype=jnp.float32)
    (loss,) = jax.jit(M.make_eval_step(spec))(flat, x, y)
    assert loss.shape == ()
    assert np.isfinite(float(loss))
