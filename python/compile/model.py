"""Layer-2: the paper's HousingMLP as pure-functional JAX train/eval steps.

The model matches the stress-test architecture of §4.2: ``hidden_layers``
densely connected layers of ``hidden_units`` units (ReLU), a linear
regression head, MSE loss, vanilla SGD (footnote 4: 100k → 32 units/layer,
1M → 100, 10M → 320).

Interface contract with the Rust runtime (``rust/src/runtime``): the model
travels as ONE flat f32 parameter vector (the controller's tensor-sequence
layout concatenated in ``ModelSpec::tensor_layout()`` order — per-layer
``w`` then ``b``, finally head ``w``/``b``):

    train_step(flat_params[P], x[B,F], y[B], lr[])  -> (flat_params'[P], loss[])
    eval_step(flat_params[P], x[B,F], y[B])         -> (loss[],)

The forward pass calls the L1 Pallas kernels (``fused_dense``); the SGD
update applies the ``sgd_update`` Pallas kernel to the flat gradient, so
both hot paths lower into the exported HLO.
"""

from dataclasses import dataclass
from typing import List, Tuple

import jax
import jax.numpy as jnp

from .kernels import fused_dense, sgd_update


@dataclass(frozen=True)
class MlpSpec:
    """Mirror of the Rust ``ModelSpec`` (keep in sync)."""

    input_dim: int
    hidden_layers: int
    hidden_units: int
    output_dim: int = 1

    def layout(self) -> List[Tuple[Tuple[int, ...], str]]:
        """Per-tensor shapes in flat-vector order, with names."""
        shapes = []
        fan_in = self.input_dim
        for l in range(self.hidden_layers):
            shapes.append(((fan_in, self.hidden_units), f"dense_{l}/w"))
            shapes.append(((self.hidden_units,), f"dense_{l}/b"))
            fan_in = self.hidden_units
        shapes.append(((fan_in, self.output_dim), "head/w"))
        shapes.append(((self.output_dim,), "head/b"))
        return shapes

    def param_count(self) -> int:
        return sum(int(jnp.prod(jnp.array(s))) for s, _ in self.layout())

    def variant_name(self) -> str:
        return (
            f"mlp_l{self.hidden_layers}_u{self.hidden_units}"
            f"_in{self.input_dim}_out{self.output_dim}"
        )


# Paper variants (§4.2 footnote 4).
PAPER_100K = MlpSpec(8, 100, 32)
PAPER_1M = MlpSpec(8, 100, 100)
PAPER_10M = MlpSpec(8, 100, 320)


def unflatten(spec: MlpSpec, flat):
    """Split the flat parameter vector into (w, b) pairs."""
    params = []
    off = 0
    for shape, _ in spec.layout():
        n = 1
        for d in shape:
            n *= d
        params.append(flat[off : off + n].reshape(shape))
        off += n
    return params


def flatten(tensors) -> jnp.ndarray:
    return jnp.concatenate([t.reshape(-1) for t in tensors])


def forward(spec: MlpSpec, flat, x, *, use_pallas: bool = True):
    """MLP forward over the flat parameter vector -> predictions [B]."""
    params = unflatten(spec, flat)
    h = x
    n_pairs = len(params) // 2
    for p in range(n_pairs):
        w, b = params[2 * p], params[2 * p + 1]
        is_head = p == n_pairs - 1
        if use_pallas:
            h = fused_dense(h, w, b, relu=not is_head)
        else:
            h = h @ w + b[None, :]
            if not is_head:
                h = jnp.maximum(h, 0.0)
    return h[:, 0]


def mse_loss(spec: MlpSpec, flat, x, y, *, use_pallas: bool = True):
    pred = forward(spec, flat, x, use_pallas=use_pallas)
    d = pred - y
    return jnp.mean(d * d)


def make_train_step(spec: MlpSpec, *, use_pallas: bool = True):
    """One vanilla-SGD step on one batch (the artifact the learner runs)."""

    def train_step(flat, x, y, lr):
        loss, grad = jax.value_and_grad(
            lambda p: mse_loss(spec, p, x, y, use_pallas=use_pallas)
        )(flat)
        new_flat = sgd_update(flat, grad, lr) if use_pallas else flat - lr * grad
        return new_flat, loss

    return train_step

def make_eval_step(spec: MlpSpec, *, use_pallas: bool = True):
    def eval_step(flat, x, y):
        return (mse_loss(spec, flat, x, y, use_pallas=use_pallas),)

    return eval_step


def init_params(spec: MlpSpec, key) -> jnp.ndarray:
    """He-initialized flat parameter vector (biases zero) — mirrors
    ``TensorModel::random_init`` on the Rust side in distribution."""
    tensors = []
    for shape, _ in spec.layout():
        if len(shape) > 1:
            key, sub = jax.random.split(key)
            scale = (2.0 / shape[0]) ** 0.5
            tensors.append(scale * jax.random.normal(sub, shape, dtype=jnp.float32))
        else:
            tensors.append(jnp.zeros(shape, dtype=jnp.float32))
    return flatten(tensors)
