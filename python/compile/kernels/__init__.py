"""Layer-1 Pallas kernels (build-time only; lowered into the L2 HLO).

All kernels run with ``interpret=True`` — the CPU PJRT plugin cannot
execute Mosaic custom-calls, so interpret mode is the correctness target
on this image; the BlockSpec structure is written for real-TPU execution
(see DESIGN.md §Hardware-Adaptation).
"""

from .dense import fused_dense
from .fedavg import lincomb, weighted_aggregate
from .sgd import sgd_update
