"""FedAvg aggregation kernels.

Two entry points:

* ``lincomb(a, b, wa, wb) = wa*a + wb*b`` over flat parameter vectors —
  the building block the Rust controller folds over N learners for the
  XLA-aggregation ablation backend (works for any learner count with one
  compiled artifact).
* ``weighted_aggregate(stack, weights)`` — the full ``Σ_j w_j · T^j``
  reduction over a stacked ``[N, D]`` block, the direct Pallas analog of
  the paper's one-thread-per-tensor OpenMP loop (Fig. 4): the grid tiles
  D; each grid step keeps a ``[N, bd]`` panel in VMEM and reduces over
  the learner axis.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _block(dim: int, preferred: int) -> int:
    b = min(dim, preferred)
    while dim % b:
        b -= 1
    return b


def _lincomb_kernel(a_ref, b_ref, wa_ref, wb_ref, o_ref):
    o_ref[...] = wa_ref[0] * a_ref[...] + wb_ref[0] * b_ref[...]


@jax.jit
def lincomb(a, b, wa, wb):
    """``wa*a + wb*b`` elementwise over flat [D] vectors; wa/wb scalars
    (passed as shape-[1] so they live in SMEM-like blocks)."""
    (d,) = a.shape
    bd = _block(d, 64 * 1024)  # 256 KiB f32 per input panel in VMEM
    wa = jnp.reshape(wa, (1,))
    wb = jnp.reshape(wb, (1,))
    return pl.pallas_call(
        _lincomb_kernel,
        out_shape=jax.ShapeDtypeStruct((d,), a.dtype),
        grid=(d // bd,),
        in_specs=[
            pl.BlockSpec((bd,), lambda i: (i,)),
            pl.BlockSpec((bd,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bd,), lambda i: (i,)),
        interpret=True,
    )(a, b, wa, wb)


def _agg_kernel(stack_ref, w_ref, o_ref):
    #

    # Reduce the learner axis of the [N, bd] VMEM panel.
    o_ref[...] = jnp.einsum(
        "n,nd->d", w_ref[...], stack_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


@jax.jit
def weighted_aggregate(stack, weights):
    """``Σ_j weights[j] * stack[j]`` for stack [N, D], weights [N]."""
    n, d = stack.shape
    assert weights.shape == (n,)
    bd = _block(d, 16 * 1024)
    return pl.pallas_call(
        _agg_kernel,
        out_shape=jax.ShapeDtypeStruct((d,), stack.dtype),
        grid=(d // bd,),
        in_specs=[
            pl.BlockSpec((n, bd), lambda i: (0, i)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bd,), lambda i: (i,)),
        interpret=True,
    )(stack, weights)
