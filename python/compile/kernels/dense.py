"""Fused dense layer: ``relu(x @ w + b)`` as a tiled Pallas kernel.

TPU mapping (DESIGN.md §Hardware-Adaptation): the grid tiles the output
``[batch, out]`` matrix into VMEM-resident blocks; each grid step streams
one ``[bm, k]`` × ``[k, bn]`` panel pair HBM→VMEM (expressed by the
BlockSpecs) and contracts it on the MXU via ``jnp.dot`` with an f32
accumulator. Block sizes are clamped multiples of the 8×128 VPU lane
layout where the model width allows.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _block(dim: int, preferred: int) -> int:
    """Largest divisor of ``dim`` that is <= preferred (keeps the grid
    exact without masking — model widths here are 32/100/320)."""
    b = min(dim, preferred)
    while dim % b:
        b -= 1
    return b


def _dense_kernel(x_ref, w_ref, b_ref, o_ref, *, relu: bool):
    # One [bm, k] x [k, bn] MXU contraction per grid step.
    acc = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    acc = acc + b_ref[...][None, :]
    if relu:
        acc = jnp.maximum(acc, 0.0)
    o_ref[...] = acc.astype(o_ref.dtype)


def _dense_forward(x, w, b, relu: bool, bm: int, bn: int):
    batch, in_dim = x.shape
    in_dim_w, out_dim = w.shape
    assert in_dim == in_dim_w, (in_dim, in_dim_w)
    assert b.shape == (out_dim,)
    bm = _block(batch, bm)
    bn = _block(out_dim, bn)
    grid = (batch // bm, out_dim // bn)
    return pl.pallas_call(
        functools.partial(_dense_kernel, relu=relu),
        out_shape=jax.ShapeDtypeStruct((batch, out_dim), x.dtype),
        grid=grid,
        in_specs=[
            # x panel: full K per (i, j) step, row block i.
            pl.BlockSpec((bm, in_dim), lambda i, j: (i, 0)),
            # w panel: full K, column block j.
            pl.BlockSpec((in_dim, bn), lambda i, j: (0, j)),
            # bias: column block j.
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(x, w, b)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _fused_dense(x, w, b, relu: bool, bm: int, bn: int):
    return _dense_forward(x, w, b, relu, bm, bn)


def _fused_dense_fwd(x, w, b, relu, bm, bn):
    out = _dense_forward(x, w, b, relu, bm, bn)
    return out, (x, w, out)


def _fused_dense_bwd(relu, bm, bn, res, g):
    # Backward: standard dense-layer cotangents. pallas_call has no
    # built-in transpose rule, so the backward matmuls are expressed in
    # plain XLA ops (they fuse into the same lowered module; the L1
    # contribution is the forward fused kernel + sgd/lincomb kernels).
    x, w, out = res
    if relu:
        g = g * (out > 0).astype(g.dtype)
    dx = g @ w.T
    dw = x.T @ g
    db = jnp.sum(g, axis=0)
    return dx, dw, db


_fused_dense.defvjp(_fused_dense_fwd, _fused_dense_bwd)


@functools.partial(jax.jit, static_argnames=("relu", "bm", "bn"))
def fused_dense(x, w, b, relu: bool = True, bm: int = 128, bn: int = 128):
    """``relu(x @ w + b)`` (or identity activation) via Pallas.

    x: [batch, in_dim]; w: [in_dim, out_dim]; b: [out_dim].
    Differentiable (custom VJP), so it can sit inside the L2 train step.
    """
    return _fused_dense(x, w, b, relu, bm, bn)
