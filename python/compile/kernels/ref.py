"""Pure-jnp oracles for every Pallas kernel (the correctness target)."""

import jax.numpy as jnp


def fused_dense_ref(x, w, b, relu: bool = True):
    out = x @ w + b[None, :]
    return jnp.maximum(out, 0.0) if relu else out


def lincomb_ref(a, b, wa, wb):
    return wa * a + wb * b


def weighted_aggregate_ref(stack, weights):
    return jnp.einsum("n,nd->d", weights, stack)


def sgd_update_ref(params, grads, lr):
    return params - lr * grads
