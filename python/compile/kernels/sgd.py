"""Fused SGD parameter update: ``p - lr * g`` as a tiled Pallas kernel.

Grid tiles the flat parameter vector; each step streams one parameter /
gradient panel pair through VMEM and writes the updated panel — a pure
VPU (elementwise) kernel, included so the whole L2 train step's update
path is Pallas end-to-end.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _block(dim: int, preferred: int) -> int:
    b = min(dim, preferred)
    while dim % b:
        b -= 1
    return b


def _sgd_kernel(p_ref, g_ref, lr_ref, o_ref):
    o_ref[...] = p_ref[...] - lr_ref[0] * g_ref[...]


@jax.jit
def sgd_update(params, grads, lr):
    """``params - lr * grads`` over flat [D] vectors; lr scalar."""
    (d,) = params.shape
    bd = _block(d, 64 * 1024)
    lr = jnp.reshape(lr, (1,))
    return pl.pallas_call(
        _sgd_kernel,
        out_shape=jax.ShapeDtypeStruct((d,), params.dtype),
        grid=(d // bd,),
        in_specs=[
            pl.BlockSpec((bd,), lambda i: (i,)),
            pl.BlockSpec((bd,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bd,), lambda i: (i,)),
        interpret=True,
    )(params, grads, lr)
