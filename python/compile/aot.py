"""AOT lowering: JAX/Pallas -> HLO text artifacts + manifest.json.

Run once via ``make artifacts`` (never on the request path):

    cd python && python -m compile.aot --out ../artifacts

Interchange format is HLO **text**, not a serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which the image's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Per model variant we export:
  * ``train_<variant>.hlo.txt``   — train_step(flat, x, y, lr) -> (flat', loss)
  * ``eval_<variant>.hlo.txt``    — eval_step(flat, x, y) -> (loss,)
  * ``lincomb_<variant>.hlo.txt`` — lincomb(a, b, wa, wb) -> (out,) over [P]
plus a ``manifest.json`` the Rust runtime reads.
"""

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels import lincomb


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True; the
    Rust side unwraps the tuple)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def export_variant(spec: M.MlpSpec, batch: int, out_dir: str) -> dict:
    name = spec.variant_name()
    p = spec.param_count()
    flat = jax.ShapeDtypeStruct((p,), jnp.float32)
    x = jax.ShapeDtypeStruct((batch, spec.input_dim), jnp.float32)
    y = jax.ShapeDtypeStruct((batch,), jnp.float32)
    lr = jax.ShapeDtypeStruct((), jnp.float32)

    files = {}
    train = jax.jit(M.make_train_step(spec), donate_argnums=(0,))
    files["train"] = f"train_{name}.hlo.txt"
    with open(os.path.join(out_dir, files["train"]), "w") as f:
        f.write(to_hlo_text(train.lower(flat, x, y, lr)))

    eval_step = jax.jit(M.make_eval_step(spec))
    files["eval"] = f"eval_{name}.hlo.txt"
    with open(os.path.join(out_dir, files["eval"]), "w") as f:
        f.write(to_hlo_text(eval_step.lower(flat, x, y)))

    vec = jax.ShapeDtypeStruct((p,), jnp.float32)
    w = jax.ShapeDtypeStruct((), jnp.float32)
    lc = jax.jit(lambda a, b, wa, wb: (lincomb(a, b, wa, wb),))
    files["lincomb"] = f"lincomb_{name}.hlo.txt"
    with open(os.path.join(out_dir, files["lincomb"]), "w") as f:
        f.write(to_hlo_text(lc.lower(vec, vec, w, w)))

    return {
        "train": files["train"],
        "eval": files["eval"],
        "lincomb": files["lincomb"],
        "param_count": p,
        "input_dim": spec.input_dim,
        "hidden_layers": spec.hidden_layers,
        "hidden_units": spec.hidden_units,
        "batch": batch,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument("--batch", type=int, default=100, help="static batch size")
    ap.add_argument(
        "--variants",
        default="tiny,small",
        help=(
            "comma list of: tiny (test-scale), small (quickstart), "
            "paper100k, paper1m, paper10m"
        ),
    )
    args = ap.parse_args()

    catalog = {
        # Test-scale variants keep `make artifacts` fast; the paper-scale
        # MLPs are exported on demand for the full benches/examples.
        "tiny": (M.MlpSpec(4, 2, 8), 16),
        "small": (M.MlpSpec(8, 4, 32), args.batch),
        "paper100k": (M.PAPER_100K, args.batch),
        "paper1m": (M.PAPER_1M, args.batch),
        "paper10m": (M.PAPER_10M, args.batch),
    }
    os.makedirs(args.out, exist_ok=True)
    manifest_path = os.path.join(args.out, "manifest.json")
    manifest = {"variants": {}}
    if os.path.exists(manifest_path):
        with open(manifest_path) as f:
            manifest = json.load(f)

    for key in args.variants.split(","):
        key = key.strip()
        if not key:
            continue
        if key not in catalog:
            sys.exit(f"unknown variant '{key}' (have {sorted(catalog)})")
        spec, batch = catalog[key]
        name = spec.variant_name()
        if name in manifest["variants"]:
            print(f"[aot] {name}: already in manifest, skipping")
            continue
        print(f"[aot] exporting {key} -> {name} (P={spec.param_count():,}, batch={batch})")
        manifest["variants"][name] = export_variant(spec, batch, args.out)

    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"[aot] wrote {manifest_path} ({len(manifest['variants'])} variants)")


if __name__ == "__main__":
    main()
